//! PJRT ↔ reference differential test — the correctness anchor for the
//! pure-Rust interpreter.
//!
//! For every artifact in `artifacts/tiny` (the compiled set), build one
//! deterministic, fully-bound input set, run it through a PJRT session
//! and a reference session over the *same* manifest, and assert every
//! output agrees within float tolerance. Requires `make artifacts`
//! (skips otherwise); CI's artifact-cached job runs it on every push.
//!
//! `_pallas` variants are skipped: the reference backend aliases them to
//! the base graphs by construction, and interpret-lowered Pallas HLO is
//! disproportionately slow to compile on the CPU PJRT client (the
//! Pallas↔XLA agreement itself is pinned by `runtime_artifacts.rs` and
//! `bench_ablation`).

use ebft::model::Manifest;
use ebft::runtime::{BackendKind, Plan, Session};
use ebft::tensor::Tensor;
use ebft::util::Pcg64;
use std::path::Path;

fn open_pair() -> Option<(Session, Session)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny not built");
        return None;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let pjrt =
        Session::open_kind(manifest.clone(), BackendKind::Pjrt).unwrap();
    let reference =
        Session::open_kind(manifest, BackendKind::Reference).unwrap();
    Some((pjrt, reference))
}

/// Bind one slot with deterministic, slot-role-appropriate data. The
/// same rng stream drives both plans, so the bound values are identical.
fn bind_slot(plan: &mut Plan<'_>, name: &str, shape: &[usize], dtype: &str,
             vocab: usize, rng: &mut Pcg64) {
    let numel: usize = shape.iter().product();
    if dtype == "i32" {
        let toks: Vec<i32> =
            (0..numel).map(|_| rng.below(vocab as u64) as i32).collect();
        plan.bind_tokens(name, &toks).unwrap();
        return;
    }
    let role = name.split('.').next().unwrap_or(name);
    let t = match role {
        // step counter ≥ 1 and a small lr — valid Adam inputs
        "t" => Tensor::scalar(3.0),
        "lr" => Tensor::scalar(1e-3),
        // binary masks at ~50% density
        "mask" => Tensor::randn(shape, 1.0, rng)
            .map(|v| if v > 0.0 { 1.0 } else { 0.0 }),
        // binary region weights (head_seq_nll)
        "weights" => Tensor::randn(shape, 1.0, rng)
            .map(|v| if v > 0.0 { 1.0 } else { 0.0 }),
        // second Adam moment must be non-negative
        "v" => Tensor::randn(shape, 0.1, rng).map(|x| x * x),
        "m" => Tensor::randn(shape, 0.01, rng),
        // activations at unit scale
        "x" | "target" => Tensor::randn(shape, 1.0, rng),
        // weights/params/adapters at small scale (keeps logits sane)
        _ => Tensor::randn(shape, 0.1, rng),
    };
    plan.bind_tensor(name, &t).unwrap();
}

#[test]
fn reference_matches_pjrt_on_every_artifact() {
    let Some((pjrt, reference)) = open_pair() else { return };
    let vocab = pjrt.manifest.dims.vocab;
    let names: Vec<String> = pjrt
        .manifest
        .artifacts
        .keys()
        .filter(|n| !n.ends_with("_pallas"))
        .cloned()
        .collect();
    assert!(names.len() >= 10, "artifact set shrank? {names:?}");

    for name in &names {
        let t0 = std::time::Instant::now();
        let spec = pjrt.manifest.artifact(name).unwrap().clone();
        let mut plan_p = pjrt.plan(name).unwrap();
        let mut plan_r = reference.plan(name).unwrap();
        // one rng per plan, same seed → identical bound values
        let mut rng_p = Pcg64::seeded(0xd1ff ^ name.len() as u64);
        let mut rng_r = Pcg64::seeded(0xd1ff ^ name.len() as u64);
        for s in &spec.inputs {
            bind_slot(&mut plan_p, &s.name, &s.shape, &s.dtype, vocab,
                      &mut rng_p);
            bind_slot(&mut plan_r, &s.name, &s.shape, &s.dtype, vocab,
                      &mut rng_r);
        }
        let outs_p = plan_p.run().unwrap();
        let outs_r = plan_r.run().unwrap();
        assert_eq!(outs_p.len(), outs_r.len(), "{name}: output arity");
        for (i, os) in spec.outputs.iter().enumerate() {
            let (p, r) = (&outs_p[i], &outs_r[i]);
            assert_eq!(p.shape, r.shape, "{name}/{}", os.name);
            let scale = p.max_abs().max(r.max_abs()).max(1.0);
            let diff = p.sub(r).max_abs();
            // f32 kernels vs XLA's fused/reordered f32: per-element
            // relative 2e-3 of the output's dynamic range
            assert!(diff <= 2e-3 * scale,
                    "artifact {name} output '{}' diverged: max|Δ| = \
                     {diff:e} against scale {scale:e}", os.name);
        }
        eprintln!("  diff {name}: {} outputs agree ({:.2}s)",
                  spec.outputs.len(), t0.elapsed().as_secs_f64());
    }
}
