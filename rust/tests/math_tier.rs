//! Integration: the numeric-tier axis (`--math` / `EBFT_MATH`).
//!
//! Lives in its own binary because [`ebft::tensor::kernels::set_math_tier`]
//! flips a process-global — running these flips inside the lib unit
//! tests would race every kernel-layer assertion. The tests here that DO
//! flip globals are serialized into one `#[test]` fn, like tests/dtype.rs.
//!
//! What is pinned here (DESIGN.md §Kernels, numeric-contract table):
//!
//! 1. `EBFT_MATH` resolution and `set_math_tier` override semantics.
//! 2. The fast tier stays within the documented per-kernel relative-
//!    error bounds of the exact tier, across awkward shapes (including
//!    lane-tail and multi-reduce-block sizes) and sparse densities.
//! 3. The fast tier is its own deterministic universe: bit-identical
//!    across 1/2/8 threads × every SIMD path the host can run (every
//!    fused op is the correctly rounded IEEE fma; scalar fast tails
//!    replay the vector ops exactly). The exact tier's matrix is
//!    re-pinned alongside for symmetry.
//! 4. Under `--dtype bf16`, the fast tier's native bf16-operand matmul
//!    cores are bit-identical to the f32 fast path on bf16-exact inputs
//!    (the pack is lossless there — any drift is a real bug).
//! 5. The tier joins the run-store fingerprint: fast runs land in
//!    distinct store cells, exact fingerprints are unchanged from the
//!    pre-tier format, and `--resume` planning never restores a record
//!    across tiers.
//!
//! CI runs this suite in the tier-1 matrix under both `EBFT_MATH=exact`
//! and `EBFT_MATH=fast`, so assertions about the resolved default are
//! written against the environment, not a constant.

use ebft::config::FtConfig;
use ebft::coordinator::{config_fingerprint, config_fingerprint_math,
                        plan_sweep, Grid, RunRecord, RunStore};
use ebft::data::Split;
use ebft::pruning::Pattern;
use ebft::runtime::BackendKind;
use ebft::tensor::dtype::{quantize_bf16, set_dtype};
use ebft::tensor::kernels::{self, SimdPath};
use ebft::tensor::sparse::{EffWeight, SparseMode};
use ebft::tensor::{Dtype, MathTier, Tensor};
use ebft::util::Pcg64;

fn env_tier() -> MathTier {
    std::env::var("EBFT_MATH")
        .ok()
        .and_then(|s| MathTier::parse(&s))
        .unwrap_or(MathTier::Exact)
}

/// Every SIMD path the running host can execute. `set_simd_path` clamps
/// an unavailable ISA to scalar, so a round-trip through the setter
/// doubles as the availability probe.
fn available_paths() -> Vec<SimdPath> {
    let prev = kernels::set_simd_path(SimdPath::Scalar);
    let mut out = vec![SimdPath::Scalar];
    for p in [SimdPath::Neon, SimdPath::Avx2, SimdPath::Avx512] {
        kernels::set_simd_path(p);
        if kernels::simd_path() == p {
            out.push(p);
        }
    }
    kernels::set_simd_path(prev);
    out
}

fn assert_bits(got: &[f32], want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: output lengths differ");
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(),
                   "{tag}: element {i} differs: {x} vs {y}");
    }
}

/// `|got−want| ≤ abs + rel·max(|got|,|want|)` elementwise; `(0, 0)`
/// degrades to the bitwise check.
fn assert_close(got: &[f32], want: &[f32], rel: f64, abs: f64, tag: &str) {
    if rel == 0.0 && abs == 0.0 {
        return assert_bits(got, want, tag);
    }
    assert_eq!(got.len(), want.len(), "{tag}: output lengths differ");
    for (i, (&x, &y)) in got.iter().zip(want).enumerate() {
        let (xf, yf) = (x as f64, y as f64);
        let lim = abs + rel * xf.abs().max(yf.abs());
        assert!((xf - yf).abs() <= lim,
                "{tag}: element {i} outside the fast-tier tolerance: \
                 {x} vs {y} (|Δ| {:.3e} > {lim:.3e})", (xf - yf).abs());
    }
}

/// One kernel invocation with its documented fast-tier `(rel, abs)`
/// bound vs the exact tier (the same numbers DESIGN.md tabulates and
/// the microbench rig enforces).
struct Case {
    name: String,
    rel: f64,
    abs: f64,
    run: Box<dyn Fn() -> Vec<f32>>,
}

/// The tier-sensitive kernel family at one (possibly awkward) shape:
/// the matmuls re-associate K-term dots through fma, the SwiGLU pair
/// swaps libm `exp` for the ≤8-ulp polynomial, the recon loss trades
/// the f64 scalar accumulator for f32 lane trees.
fn build_cases(m: usize, k: usize, n: usize, seed: u64) -> Vec<Case> {
    let mut rng = Pcg64::seeded(seed);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    let at = kernels::transpose(&a).unwrap();
    let bt = kernels::transpose(&b).unwrap();
    let gate = Tensor::randn(&[m, n], 1.0, &mut rng);
    let up = Tensor::randn(&[m, n], 1.0, &mut rng);
    let dh = Tensor::randn(&[m, n], 1.0, &mut rng);
    let target = Tensor::randn(&[m, n], 1.0, &mut rng);

    let mut cases: Vec<Case> = Vec::new();
    let mut case = |name: &str, rel: f64, abs: f64,
                    run: Box<dyn Fn() -> Vec<f32>>| {
        cases.push(Case { name: name.to_string(), rel, abs, run });
    };
    let (a1, b1) = (a.clone(), b.clone());
    case("matmul", 1e-4, 1e-3,
         Box::new(move || kernels::matmul(&a1, &b1).unwrap().data));
    let b2 = b.clone();
    case("matmul_at_b", 1e-4, 1e-3,
         Box::new(move || kernels::matmul_at_b(&at, &b2).unwrap().data));
    let a3 = a.clone();
    case("matmul_a_bt", 1e-4, 1e-3,
         Box::new(move || kernels::matmul_a_bt(&a3, &bt).unwrap().data));
    case("gram", 1e-4, 1e-3,
         Box::new(move || kernels::gram(&a).unwrap().data));
    let (g5, u5) = (gate.clone(), up.clone());
    case("silu_mul", 1e-5, 1e-5,
         Box::new(move || kernels::silu_mul(&g5, &u5).data));
    let g6 = gate.clone();
    case("silu_mul_bwd", 1e-5, 1e-5,
         Box::new(move || {
             let (dg, du) = kernels::silu_mul_bwd(&dh, &g6, &up);
             let mut out = dg.data;
             out.extend(du.data);
             out
         }));
    case("recon_loss_grad", 1e-3, 1e-5,
         Box::new(move || {
             let (loss, dy) = kernels::recon_loss_grad(&gate, &target);
             let mut out = vec![loss];
             out.extend(dy.data);
             out
         }));
    cases
}

/// Sparse matmuls across densities: the compressed-format axpy cores
/// funnel through the same tier-dispatched `axpy`, and the density
/// moves which format the dispatcher picks.
fn sparse_cases(seed: u64) -> Vec<Case> {
    let (m, k, n) = (7usize, 67usize, 45usize);
    let mut rng = Pcg64::seeded(seed);
    let mut out: Vec<Case> = Vec::new();
    for keep in [0.25f32, 0.5, 0.9] {
        let w = Tensor::randn(&[k, n], 1.0, &mut rng);
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mut mask = Tensor::zeros(&[k, n]);
        for v in mask.data.iter_mut() {
            *v = (rng.next_f32() < keep) as u32 as f32;
        }
        let eff = EffWeight::from_masked_mode(&w, &mask, SparseMode::Force);
        out.push(Case {
            name: format!("sparse/{}@{keep}", eff.format()),
            rel: 1e-4,
            abs: 1e-3,
            run: Box::new(move || eff.matmul(&x).unwrap().data),
        });
    }
    out
}

/// The tolerance + bit-determinism matrix for one case set: exact and
/// fast goldens at (scalar, 1 thread), fast within tolerance of exact,
/// then both tiers bit-identical to their golden across 1/2/8 threads ×
/// every available SIMD path.
fn check_cases(cases: &[Case], paths: &[SimdPath], shape: &str) {
    kernels::set_simd_path(SimdPath::Scalar);
    kernels::set_threads(1);
    kernels::set_math_tier(MathTier::Exact);
    let exact: Vec<Vec<f32>> = cases.iter().map(|c| (c.run)()).collect();
    kernels::set_math_tier(MathTier::Fast);
    let fast: Vec<Vec<f32>> = cases.iter().map(|c| (c.run)()).collect();
    for (c, (e, f)) in cases.iter().zip(exact.iter().zip(&fast)) {
        assert_close(f, e, c.rel, c.abs,
                     &format!("{}/{shape} fast vs exact", c.name));
    }
    for (tier, goldens) in [(MathTier::Exact, &exact),
                            (MathTier::Fast, &fast)] {
        kernels::set_math_tier(tier);
        for &p in paths {
            kernels::set_simd_path(p);
            for t in [1usize, 2, 8] {
                kernels::set_threads(t);
                for (c, g) in cases.iter().zip(goldens) {
                    assert_bits(&(c.run)(), g,
                                &format!("{}/{shape} {} {} at {t} threads",
                                         c.name, tier.as_str(), p.as_str()));
                }
            }
        }
    }
    kernels::set_simd_path(SimdPath::Scalar);
    kernels::set_threads(1);
    kernels::set_math_tier(MathTier::Exact);
}

#[test]
fn math_tier_suite() {
    // --- resolution order: env default, then set_math_tier wins ---
    let initial = env_tier();
    assert_eq!(kernels::math_tier(), initial,
               "first resolution must follow EBFT_MATH (or Exact)");
    assert_eq!(MathTier::parse("FAST"), Some(MathTier::Fast));
    assert_eq!(MathTier::parse(" exact "), Some(MathTier::Exact));
    assert_eq!(MathTier::parse("fastest"), None);
    let prev_tier = kernels::set_math_tier(MathTier::Exact);
    assert_eq!(prev_tier, initial,
               "set_math_tier must return the prior setting");
    // the suite drives tiers itself; pin f32 storage so the fast-tier
    // matmuls don't engage the bf16 pack on non-bf16-exact inputs when
    // CI's dtype matrix exports EBFT_DTYPE=bf16
    let prev_dtype = set_dtype(Dtype::F32);
    let prev_path = kernels::set_simd_path(SimdPath::Scalar);
    let prev_threads = kernels::set_threads(1);
    let paths = available_paths();

    // --- tolerance + determinism across awkward shapes: degenerate,
    // sub-lane, lane-tail (4097 = 256·16 + 1), and a gate large enough
    // to span multiple 4096-element reduction blocks (33·257 = 8481) ---
    for &(m, k, n, seed) in &[(1usize, 1usize, 1usize, 11u64),
                              (3, 5, 7, 12),
                              (17, 33, 9, 13),
                              (5, 4097, 3, 14),
                              (33, 64, 257, 15)] {
        check_cases(&build_cases(m, k, n, seed), &paths,
                    &format!("{m}x{k}x{n}"));
    }

    // --- sparse formats across densities ---
    check_cases(&sparse_cases(77), &paths, "7x67x45");

    // --- bf16 compute: on bf16-exact inputs the native bf16-operand
    // cores are a lossless re-encoding of the f32 fast path ---
    let mut rng = Pcg64::seeded(99);
    let mut a = Tensor::randn(&[9, 130], 1.0, &mut rng);
    let mut b = Tensor::randn(&[130, 37], 1.0, &mut rng);
    for v in a.data.iter_mut().chain(b.data.iter_mut()) {
        *v = quantize_bf16(*v);
    }
    let bt = kernels::transpose(&b).unwrap();
    kernels::set_math_tier(MathTier::Fast);
    let f32_mm = kernels::matmul(&a, &b).unwrap().data;
    let f32_abt = kernels::matmul_a_bt(&a, &bt).unwrap().data;
    set_dtype(Dtype::Bf16);
    for &p in &paths {
        kernels::set_simd_path(p);
        assert_bits(&kernels::matmul(&a, &b).unwrap().data, &f32_mm,
                    &format!("bf16-native matmul on {}", p.as_str()));
        assert_bits(&kernels::matmul_a_bt(&a, &bt).unwrap().data, &f32_abt,
                    &format!("bf16-native matmul_a_bt on {}", p.as_str()));
    }
    set_dtype(Dtype::F32);

    // --- restore every global the suite touched ---
    set_dtype(prev_dtype);
    kernels::set_simd_path(prev_path);
    kernels::set_threads(prev_threads);
    kernels::set_math_tier(prev_tier);
}

// ---------------------------------------------------------------------
// fingerprint membership — pure store/planning tests, no global flips
// ---------------------------------------------------------------------

fn sample_record(math: MathTier, simd_path: &str) -> RunRecord {
    RunRecord {
        pruner: "wanda".into(),
        pruner_label: "wanda".into(),
        pattern: Pattern::Unstructured(0.5),
        pattern_label: Pattern::Unstructured(0.5).label(),
        recovery: "none".into(),
        recovery_label: "none".into(),
        ppl: 12.5,
        sparsity: 0.5,
        layer_sparsity: Vec::new(),
        prune_secs: 1.5,
        ft_secs: 2.25,
        eval_secs: 0.25,
        peak_resident_bytes: 0,
        math,
        simd_path: simd_path.into(),
        ebft_report: None,
    }
}

#[test]
fn fast_tier_fingerprints_are_distinct_and_resume_never_mixes_tiers() {
    let ft = FtConfig::default();
    let args = ("small", "small-seed0-steps400", 7u64, &ft, 64usize,
                "xla", Split::WikiSim, BackendKind::Reference, Dtype::F32);
    // the exact tier IS the pre-tier fingerprint, byte for byte — old
    // stores stay resumable without migration
    let exact_fp = config_fingerprint(args.0, args.1, args.2, args.3,
                                      args.4, args.5, args.6, args.7,
                                      args.8);
    assert_eq!(config_fingerprint_math(args.0, args.1, args.2, args.3,
                                       args.4, args.5, args.6, args.7,
                                       args.8, MathTier::Exact),
               exact_fp);
    // fast moves the numbers, so it must move the fingerprint
    let fast_fp = config_fingerprint_math(args.0, args.1, args.2, args.3,
                                          args.4, args.5, args.6, args.7,
                                          args.8, MathTier::Fast);
    assert_ne!(fast_fp, exact_fp);
    assert_eq!(fast_fp.len(), 16);
    assert!(fast_fp.chars().all(|c| c.is_ascii_hexdigit()));

    // records of the two tiers land in distinct store cells, and resume
    // planning keyed by one tier's fingerprint never sees the other's
    let dir = std::env::temp_dir()
        .join(format!("ebft-mathtier-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let store = RunStore::open(&dir).unwrap();
    store.put_record(&exact_fp, &sample_record(MathTier::Exact, ""))
        .unwrap();
    store.put_record(&fast_fp, &sample_record(MathTier::Fast, "avx2"))
        .unwrap();
    assert!(dir.join(&exact_fp).join("cells").is_dir());
    assert!(dir.join(&fast_fp).join("cells").is_dir());

    let grid = Grid::new(&["wanda"], &[Pattern::Unstructured(0.5)],
                         &["none"]).unwrap();
    let plan_exact = plan_sweep(&grid, |key| {
        store.get_record(&exact_fp, key).unwrap()
    }).unwrap();
    let restored: Vec<&RunRecord> =
        plan_exact.restored.iter().flatten().collect();
    assert_eq!(restored.len(), 1);
    assert_eq!(restored[0].math, MathTier::Exact);
    assert!(restored[0].simd_path.is_empty());

    let plan_fast = plan_sweep(&grid, |key| {
        store.get_record(&fast_fp, key).unwrap()
    }).unwrap();
    let restored: Vec<&RunRecord> =
        plan_fast.restored.iter().flatten().collect();
    assert_eq!(restored.len(), 1);
    assert_eq!(restored[0].math, MathTier::Fast);
    assert_eq!(restored[0].simd_path, "avx2");

    // a tier with no completed cells resumes from scratch — the other
    // tier's records never shadow it
    let untouched_fp = config_fingerprint_math(
        args.0, "other-dense", args.2, args.3, args.4, args.5, args.6,
        args.7, args.8, MathTier::Fast);
    let plan_empty = plan_sweep(&grid, |key| {
        store.get_record(&untouched_fp, key).unwrap()
    }).unwrap();
    assert!(plan_empty.restored.iter().all(|r| r.is_none()));
    assert!(plan_empty.groups.iter().all(|g| g.need_prune));

    std::fs::remove_dir_all(&dir).ok();
}
