//! Integration: the storage-dtype axis (`--dtype` / `EBFT_DTYPE`).
//!
//! Lives in its own binary because [`ebft::tensor::dtype::set_dtype`]
//! flips a process-global — running these flips inside the lib unit
//! tests would race every test that crosses a storage boundary. The
//! tests here that DO flip the global are serialized into one `#[test]`
//! fn for the same reason.
//!
//! CI runs this suite under both `EBFT_DTYPE=f32` and `EBFT_DTYPE=bf16`
//! (the tier-1 dtype matrix), so assertions about the resolved default
//! are written against the environment, not a constant.

use ebft::model::synth::{write_synthetic, SynthConfig};
use ebft::model::ParamStore;
use ebft::tensor::dtype::{self, is_bf16_exact, quantize_bf16, Dtype};
use ebft::tensor::kernels::{self, SimdPath};
use std::path::PathBuf;

fn env_default() -> Dtype {
    std::env::var("EBFT_DTYPE")
        .ok()
        .and_then(|s| Dtype::parse(&s))
        .unwrap_or(Dtype::F32)
}

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("ebft-dtype-{tag}-{}", std::process::id()))
}

#[test]
fn report_simd_path() {
    // the tier-1 job summary greps this exact prefix out of the dtype
    // matrix log (see ci.yml) to surface the chosen SIMD path per run
    println!("simd-path: {} (detected: {}, dtype: {})",
             kernels::simd_path().as_str(),
             SimdPath::detected().as_str(),
             dtype::active_dtype().as_str());
}

#[test]
fn conversion_bounds_against_known_values() {
    // bf16 keeps an 8-bit mantissa: relative error ≤ 2^-8 for normals,
    // exact for values already on the bf16 grid
    for v in [1.0f32, -1.0, 0.5, 2.0, 256.0, 0.0, -0.0] {
        assert_eq!(quantize_bf16(v).to_bits(), v.to_bits(), "{v}");
        assert!(is_bf16_exact(v));
    }
    assert_eq!(quantize_bf16(1.00390625), 1.0); // midpoint → even
    // one f32 ulp above the midpoint rounds up (a decimal literal like
    // 1.0039063 would itself parse to the midpoint and round down)
    let above = f32::from_bits(1.00390625f32.to_bits() + 1);
    assert_eq!(quantize_bf16(above), 1.0078125);
    for v in [std::f32::consts::PI, -0.1, 123.456, 3e-3, 1e30] {
        let q = quantize_bf16(v);
        assert!((q - v).abs() <= v.abs() * 3.9e-3, "{v} -> {q}");
        assert!(is_bf16_exact(q));
    }
    assert!(quantize_bf16(f32::NAN).is_nan());
}

#[test]
fn dtype_global_and_bf16_checkpoints() {
    // --- resolution order: env default, then set_dtype wins ---
    let initial = env_default();
    assert_eq!(dtype::active_dtype(), initial,
               "first resolution must follow EBFT_DTYPE (or F32)");
    let prev = dtype::set_dtype(Dtype::Bf16);
    assert_eq!(prev, initial, "set_dtype must return the prior setting");
    assert_eq!(dtype::active_dtype(), Dtype::Bf16);

    // --- bf16 storage boundary: params off init_params.bin are
    // rounded, so every stored value sits on the bf16 grid ---
    let dir = scratch("ckpt");
    let manifest = write_synthetic(&dir, &SynthConfig::tiny()).unwrap();
    let store_bf = ParamStore::from_init_bin(&manifest).unwrap();
    for (name, t) in store_bf.names.iter().zip(&store_bf.tensors) {
        assert!(t.data.iter().all(|&v| is_bf16_exact(v)),
                "{name}: loaded under bf16 but not on the bf16 grid");
    }

    // --- .ebft v2 bf16 payloads round-trip bit-exactly ---
    let p_bf = dir.join("params.bf16.ebft");
    store_bf.save_compact(&p_bf).unwrap();
    let loaded = ParamStore::load(&p_bf, &manifest).unwrap();
    for ((name, a), b) in
        store_bf.names.iter().zip(&store_bf.tensors).zip(&loaded.tensors)
    {
        assert_eq!(a.shape, b.shape, "{name}");
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{name}[{i}]: bf16 compact round-trip moved a bit");
        }
    }

    // --- the bf16 payload halves the compact checkpoint: ≤55% of the
    // same store's f32 compact encoding (2 vs 4 bytes/value, plus
    // shared per-tensor headers) ---
    dtype::set_dtype(Dtype::F32);
    let store_f32 = ParamStore::from_init_bin(&manifest).unwrap();
    let p_f32 = dir.join("params.f32.ebft");
    store_f32.save_compact(&p_f32).unwrap();
    let size_bf = std::fs::metadata(&p_bf).unwrap().len();
    let size_f32 = std::fs::metadata(&p_f32).unwrap().len();
    assert!(size_bf as f64 <= 0.55 * size_f32 as f64,
            "bf16 compact checkpoint is {size_bf} bytes vs {size_f32} \
             f32 bytes — expected ≤55%");

    // dtype moves stored numbers (unlike threads / the SIMD path):
    // the two loads really differ, which is why the run-store
    // fingerprint carries the dtype
    let differs = store_bf
        .tensors
        .iter()
        .zip(&store_f32.tensors)
        .any(|(a, b)| {
            a.data.iter().zip(&b.data).any(|(x, y)| x.to_bits() != y.to_bits())
        });
    assert!(differs, "bf16 quantization changed nothing — init values \
                      all landed on the bf16 grid?");

    dtype::set_dtype(initial);
    let _ = std::fs::remove_dir_all(&dir);
}
