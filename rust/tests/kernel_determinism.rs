//! Kernel-layer determinism at the artifact level: the reference
//! backend's `block_ft_step` (the EBFT hot loop — masked-gradient Adam
//! through the full block forward/backward) must produce bit-identical
//! outputs under `EBFT_THREADS=1/2/8`. This is the contract that lets
//! `--threads`/`EBFT_THREADS` move wall-clock without touching
//! `backend_diff` pins, run-store resume byte-identity, or any recorded
//! number. Runs artifact-free on a synthetic tiny manifest.

use ebft::model::synth::{write_synthetic, SynthConfig};
use ebft::model::ParamStore;
use ebft::runtime::{BackendKind, DeviceBuffer, Session};
use ebft::tensor::{kernels, Tensor};
use ebft::util::Pcg64;

fn open_session(tag: &str) -> Session {
    let dir = std::env::temp_dir().join(format!(
        "ebft-kdet-{tag}-{}", std::process::id()));
    let manifest = write_synthetic(&dir, &SynthConfig::tiny()).unwrap();
    Session::open_kind(manifest, BackendKind::Reference).unwrap()
}

/// Random binary mask with ~50% zeros.
fn random_mask(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n)
        .map(|_| if rng.below(2) == 0 { 0.0 } else { 1.0 })
        .collect();
    Tensor::from_vec(shape, data)
}

/// One `block_ft_step` execution with every input freshly bound,
/// returning all 28 outputs as f32 bit patterns.
fn run_ft_step(session: &Session, bp: &[Tensor], masks: &[Tensor],
               x: &Tensor, target: &Tensor) -> Vec<Vec<u32>> {
    let mut plan = session.plan("block_ft_step").unwrap();
    plan.bind_indexed("bp", bp.iter()).unwrap();
    plan.bind_indexed("mask", masks.iter()).unwrap();
    for (j, t) in bp.iter().enumerate() {
        let z = DeviceBuffer::zeros(&t.shape).unwrap();
        plan.bind(&format!("m.{j}"), &z).unwrap();
        plan.bind(&format!("v.{j}"), &z).unwrap();
    }
    plan.bind_scalar("t", 1.0).unwrap();
    plan.bind_scalar("lr", 1e-2).unwrap();
    plan.bind_tensor("x", x).unwrap();
    plan.bind_tensor("target", target).unwrap();
    plan.run_to_device()
        .unwrap()
        .iter()
        .map(|o| {
            o.fetch().unwrap().data.iter().map(|v| v.to_bits()).collect()
        })
        .collect()
}

#[test]
fn block_ft_step_bit_identical_across_thread_counts() {
    let session = open_session("ftstep");
    let manifest = &session.manifest;
    let d = manifest.dims.clone();

    let dense = ParamStore::from_init_bin(manifest).unwrap();
    let bp: Vec<Tensor> = dense
        .block_params(manifest, 0)
        .into_iter()
        .cloned()
        .collect();
    let mut rng = Pcg64::seeded(0xde7);
    let masks: Vec<Tensor> = manifest
        .block_linear_shapes(0)
        .iter()
        .map(|s| random_mask(s, &mut rng))
        .collect();
    let act = [d.batch, d.seq, d.d_model];
    let x = Tensor::randn(&act, 0.5, &mut rng);
    let target = Tensor::randn(&act, 0.5, &mut rng);

    let prev = kernels::set_threads(1);
    let serial = run_ft_step(&session, &bp, &masks, &x, &target);
    assert_eq!(serial.len(), 28, "bp×9 + m×9 + v×9 + loss");
    for t in [2usize, 8] {
        kernels::set_threads(t);
        let outs = run_ft_step(&session, &bp, &masks, &x, &target);
        for (oi, (a, b)) in serial.iter().zip(&outs).enumerate() {
            assert_eq!(a, b,
                       "output {oi} differs between EBFT_THREADS=1 and \
                        EBFT_THREADS={t}");
        }
    }
    kernels::set_threads(prev);
}

/// The full-model train step exercises embed/head/attention backwards
/// and the LM-head softmax reduction on top of the block path — same
/// contract, one level up.
#[test]
fn lm_train_step_bit_identical_across_thread_counts() {
    let session = open_session("lmstep");
    let manifest = &session.manifest;
    let d = manifest.dims.clone();

    let dense = ParamStore::from_init_bin(manifest).unwrap();
    let mut rng = Pcg64::seeded(0x1337);
    let tokens: Vec<i32> = (0..d.batch * d.seq)
        .map(|_| rng.below(d.vocab as u64) as i32)
        .collect();

    let run = |_label: &str| -> Vec<Vec<u32>> {
        let mut plan = session.plan("lm_train_step").unwrap();
        plan.bind_indexed("param", dense.tensors.iter()).unwrap();
        for (j, t) in dense.tensors.iter().enumerate() {
            let z = DeviceBuffer::zeros(&t.shape).unwrap();
            plan.bind(&format!("m.{j}"), &z).unwrap();
            plan.bind(&format!("v.{j}"), &z).unwrap();
        }
        plan.bind_scalar("t", 1.0).unwrap();
        plan.bind_scalar("lr", 3e-3).unwrap();
        plan.bind_tokens("tokens", &tokens).unwrap();
        plan.run_to_device()
            .unwrap()
            .iter()
            .map(|o| {
                o.fetch().unwrap().data.iter().map(|v| v.to_bits())
                    .collect()
            })
            .collect()
    };

    let prev = kernels::set_threads(1);
    let serial = run("serial");
    for t in [2usize, 8] {
        kernels::set_threads(t);
        let outs = run("parallel");
        for (oi, (a, b)) in serial.iter().zip(&outs).enumerate() {
            assert_eq!(a, b,
                       "lm_train_step output {oi} differs at \
                        EBFT_THREADS={t}");
        }
    }
    kernels::set_threads(prev);
}
