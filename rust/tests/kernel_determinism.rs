//! Kernel-layer determinism at the artifact level: the reference
//! backend's `block_ft_step` (the EBFT hot loop — masked-gradient Adam
//! through the full block forward/backward) must produce bit-identical
//! outputs under `EBFT_THREADS=1/2/8`. This is the contract that lets
//! `--threads`/`EBFT_THREADS` move wall-clock without touching
//! `backend_diff` pins, run-store resume byte-identity, or any recorded
//! number. Runs artifact-free on a synthetic tiny manifest.

use ebft::masks::MaskSet;
use ebft::model::synth::{write_synthetic, SynthConfig};
use ebft::model::ParamStore;
use ebft::runtime::{BackendKind, DeviceBuffer, Session};
use ebft::serve::{Decoder, Sampler, Sampling};
use ebft::tensor::{kernels, Tensor};
use ebft::util::Pcg64;

fn open_session(tag: &str) -> Session {
    let dir = std::env::temp_dir().join(format!(
        "ebft-kdet-{tag}-{}", std::process::id()));
    let manifest = write_synthetic(&dir, &SynthConfig::tiny()).unwrap();
    Session::open_kind(manifest, BackendKind::Reference).unwrap()
}

/// Random binary mask with ~50% zeros.
fn random_mask(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n)
        .map(|_| if rng.below(2) == 0 { 0.0 } else { 1.0 })
        .collect();
    Tensor::from_vec(shape, data)
}

/// One `block_ft_step` execution with every input freshly bound,
/// returning all 28 outputs as f32 bit patterns.
fn run_ft_step(session: &Session, bp: &[Tensor], masks: &[Tensor],
               x: &Tensor, target: &Tensor) -> Vec<Vec<u32>> {
    let mut plan = session.plan("block_ft_step").unwrap();
    plan.bind_indexed("bp", bp.iter()).unwrap();
    plan.bind_indexed("mask", masks.iter()).unwrap();
    for (j, t) in bp.iter().enumerate() {
        let z = DeviceBuffer::zeros(&t.shape).unwrap();
        plan.bind(&format!("m.{j}"), &z).unwrap();
        plan.bind(&format!("v.{j}"), &z).unwrap();
    }
    plan.bind_scalar("t", 1.0).unwrap();
    plan.bind_scalar("lr", 1e-2).unwrap();
    plan.bind_tensor("x", x).unwrap();
    plan.bind_tensor("target", target).unwrap();
    plan.run_to_device()
        .unwrap()
        .iter()
        .map(|o| {
            o.fetch().unwrap().data.iter().map(|v| v.to_bits()).collect()
        })
        .collect()
}

#[test]
fn block_ft_step_bit_identical_across_thread_counts() {
    let session = open_session("ftstep");
    let manifest = &session.manifest;
    let d = manifest.dims.clone();

    let dense = ParamStore::from_init_bin(manifest).unwrap();
    let bp: Vec<Tensor> = dense
        .block_params(manifest, 0)
        .into_iter()
        .cloned()
        .collect();
    let mut rng = Pcg64::seeded(0xde7);
    let masks: Vec<Tensor> = manifest
        .block_linear_shapes(0)
        .iter()
        .map(|s| random_mask(s, &mut rng))
        .collect();
    let act = [d.batch, d.seq, d.d_model];
    let x = Tensor::randn(&act, 0.5, &mut rng);
    let target = Tensor::randn(&act, 0.5, &mut rng);

    let prev = kernels::set_threads(1);
    let serial = run_ft_step(&session, &bp, &masks, &x, &target);
    assert_eq!(serial.len(), 28, "bp×9 + m×9 + v×9 + loss");
    for t in [2usize, 8] {
        kernels::set_threads(t);
        let outs = run_ft_step(&session, &bp, &masks, &x, &target);
        for (oi, (a, b)) in serial.iter().zip(&outs).enumerate() {
            assert_eq!(a, b,
                       "output {oi} differs between EBFT_THREADS=1 and \
                        EBFT_THREADS={t}");
        }
    }
    kernels::set_threads(prev);
}

/// The full-model train step exercises embed/head/attention backwards
/// and the LM-head softmax reduction on top of the block path — same
/// contract, one level up.
#[test]
fn lm_train_step_bit_identical_across_thread_counts() {
    let session = open_session("lmstep");
    let manifest = &session.manifest;
    let d = manifest.dims.clone();

    let dense = ParamStore::from_init_bin(manifest).unwrap();
    let mut rng = Pcg64::seeded(0x1337);
    let tokens: Vec<i32> = (0..d.batch * d.seq)
        .map(|_| rng.below(d.vocab as u64) as i32)
        .collect();

    let run = |_label: &str| -> Vec<Vec<u32>> {
        let mut plan = session.plan("lm_train_step").unwrap();
        plan.bind_indexed("param", dense.tensors.iter()).unwrap();
        for (j, t) in dense.tensors.iter().enumerate() {
            let z = DeviceBuffer::zeros(&t.shape).unwrap();
            plan.bind(&format!("m.{j}"), &z).unwrap();
            plan.bind(&format!("v.{j}"), &z).unwrap();
        }
        plan.bind_scalar("t", 1.0).unwrap();
        plan.bind_scalar("lr", 3e-3).unwrap();
        plan.bind_tokens("tokens", &tokens).unwrap();
        plan.run_to_device()
            .unwrap()
            .iter()
            .map(|o| {
                o.fetch().unwrap().data.iter().map(|v| v.to_bits())
                    .collect()
            })
            .collect()
    };

    let prev = kernels::set_threads(1);
    let serial = run("serial");
    for t in [2usize, 8] {
        kernels::set_threads(t);
        let outs = run("parallel");
        for (oi, (a, b)) in serial.iter().zip(&outs).enumerate() {
            assert_eq!(a, b,
                       "lm_train_step output {oi} differs at \
                        EBFT_THREADS={t}");
        }
    }
    kernels::set_threads(prev);
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

/// Greedy-decode through the serving [`Decoder`] until the KV cache is
/// full, returning the chosen tokens (prompt + generated) and the logits
/// bit patterns at every position.
fn greedy_decode(session: &Session, params: &ParamStore, masks: &MaskSet,
                 prompt: &[i32]) -> (Vec<i32>, Vec<Vec<u32>>) {
    let mut dec = Decoder::new(session, params, masks).unwrap();
    let mut sampler = Sampler::new(Sampling::Greedy, 0);
    let mut tokens = prompt.to_vec();
    let mut logits_bits = Vec::new();
    let mut logits = Tensor::zeros(&[0]);
    for &t in prompt {
        logits = dec.step(t).unwrap();
        logits_bits.push(bits(&logits));
    }
    while dec.remaining() > 0 {
        let next = sampler.next_token(&logits.data).unwrap();
        tokens.push(next);
        logits = dec.step(next).unwrap();
        logits_bits.push(bits(&logits));
    }
    assert_eq!(tokens.len(), session.manifest.dims.seq);
    assert_eq!(logits_bits.len(), session.manifest.dims.seq);
    (tokens, logits_bits)
}

/// Full (batched, non-incremental) forward over `tokens` through the
/// `embed_fwd` → `block_fwd`× → `head_decode` artifacts, returning the
/// per-position next-token logits bit patterns of batch row 0.
fn full_forward_logits(session: &Session, params: &ParamStore,
                       masks: &MaskSet, tokens: &[i32]) -> Vec<Vec<u32>> {
    let manifest = &session.manifest;
    let d = manifest.dims.clone();
    assert_eq!(tokens.len(), d.seq);
    // every batch row carries the same sequence; causal attention makes
    // rows independent, so row 0 is what the decoder must reproduce
    let mut padded = Vec::with_capacity(d.batch * d.seq);
    for _ in 0..d.batch {
        padded.extend_from_slice(tokens);
    }
    let mut embed = session.plan("embed_fwd").unwrap();
    embed.bind_tensor("embed", params.get("embed").unwrap()).unwrap();
    embed.bind_tokens("tokens", &padded).unwrap();
    let mut x = embed.run_to_device().unwrap().remove(0);
    for l in 0..d.n_layers {
        let mut p = session.plan("block_fwd").unwrap();
        p.bind_indexed("bp", params.block_params(manifest, l)).unwrap();
        p.bind_indexed("mask", masks.block(l).iter()).unwrap();
        p.bind("x", &x).unwrap();
        x = p.run_to_device().unwrap().remove(0);
    }
    let y = x.fetch().unwrap();
    let mut head = session.plan("head_decode").unwrap();
    head.bind_tensor("g_norm", params.get("final.norm.g").unwrap())
        .unwrap();
    head.bind_tensor("head", params.get("final.head").unwrap()).unwrap();
    (0..d.seq)
        .map(|t| {
            let row = Tensor::from_vec(
                &[1, d.d_model],
                y.data[t * d.d_model..(t + 1) * d.d_model].to_vec());
            head.bind_tensor("x", &row).unwrap();
            let logits = head.run_to_device().unwrap()[0].fetch().unwrap();
            bits(&logits)
        })
        .collect()
}

/// The serving contract (DESIGN.md §Serving): a greedy KV-cache decode
/// emits, at every position, logits bit-identical to a full batched
/// forward over the same prefix — and both are bit-identical across
/// kernel thread counts, so serving numerics are schedule-invariant.
#[test]
fn greedy_decode_bit_identical_to_full_forward_across_threads() {
    let session = open_session("decode");
    let manifest = &session.manifest;
    let d = manifest.dims.clone();
    let params = ParamStore::from_init_bin(manifest).unwrap();
    let mut rng = Pcg64::seeded(0x5e12);
    // a pruned (random ~50%-sparse) base, like the serving deployment
    let mut masks = MaskSet::dense(manifest);
    for l in 0..d.n_layers {
        for (j, s) in manifest.block_linear_shapes(l).iter().enumerate() {
            masks.masks[l][j] = random_mask(s, &mut rng);
        }
    }
    let prompt: Vec<i32> = (0..4)
        .map(|_| rng.below(d.vocab as u64) as i32)
        .collect();

    let prev = kernels::set_threads(1);
    let (tokens1, dec1) = greedy_decode(&session, &params, &masks,
                                        &prompt);
    let full1 = full_forward_logits(&session, &params, &masks, &tokens1);
    for (t, (a, b)) in dec1.iter().zip(&full1).enumerate() {
        assert_eq!(a, b,
                   "decode logits at position {t} differ from the full \
                    forward over the same prefix");
    }
    for th in [2usize, 8] {
        kernels::set_threads(th);
        let (tokens, dec) = greedy_decode(&session, &params, &masks,
                                          &prompt);
        assert_eq!(tokens, tokens1,
                   "greedy token stream changed at EBFT_THREADS={th}");
        assert_eq!(dec, dec1,
                   "decode logits changed at EBFT_THREADS={th}");
        assert_eq!(full_forward_logits(&session, &params, &masks, &tokens),
                   full1,
                   "full-forward logits changed at EBFT_THREADS={th}");
    }
    kernels::set_threads(prev);
}
