//! Serving-layer integration tests on the synthetic tiny manifest +
//! reference backend: `.ebft` adapter export/import round-trip through
//! the [`AdapterRegistry`], and the continuous-batching engine's
//! contracts — scheduling-invariant token streams, overlapped
//! sequences, deadlines, and clean completion accounting.

use ebft::ebft::lora;
use ebft::masks::MaskSet;
use ebft::model::synth::{write_synthetic, SynthConfig};
use ebft::model::{Manifest, ParamStore};
use ebft::runtime::{BackendKind, Session};
use ebft::serve::{serve, AdapterRegistry, Finish, Request, Sampling,
                  ServeConfig, BASE_TENANT};
use ebft::tensor::Tensor;
use ebft::util::Pcg64;

fn artifact_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("ebft-serve-{tag}-{}",
                                      std::process::id()))
}

fn open_session(tag: &str) -> (Session, std::path::PathBuf) {
    let dir = artifact_dir(tag);
    let manifest = write_synthetic(&dir, &SynthConfig::tiny()).unwrap();
    (Session::open_kind(manifest, BackendKind::Reference).unwrap(), dir)
}

/// Random binary mask with ~50% zeros.
fn random_mask(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n)
        .map(|_| if rng.below(2) == 0 { 0.0 } else { 1.0 })
        .collect();
    Tensor::from_vec(shape, data)
}

fn random_masks(manifest: &Manifest, seed: u64) -> MaskSet {
    let mut rng = Pcg64::seeded(seed);
    let mut masks = MaskSet::dense(manifest);
    for l in 0..manifest.dims.n_layers {
        for (j, s) in manifest.block_linear_shapes(l).iter().enumerate() {
            masks.masks[l][j] = random_mask(s, &mut rng);
        }
    }
    masks
}

/// Random A *and* B (unlike training init, where B = 0) so the merged
/// model actually differs from the base.
fn random_adapters(manifest: &Manifest, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg64::seeded(seed);
    manifest
        .lora_shapes()
        .iter()
        .map(|s| Tensor::randn(s, 0.05, &mut rng))
        .collect()
}

#[test]
fn adapter_export_import_round_trip_through_registry() {
    let (session, dir) = open_session("roundtrip");
    let manifest = session.manifest.clone();
    let params = ParamStore::from_init_bin(&manifest).unwrap();
    let masks = random_masks(&manifest, 0xada);
    let adapters = random_adapters(&manifest, 0xbeef);

    let path = dir.join("tenant0.ebft");
    lora::save_adapters(&manifest, &adapters, &path).unwrap();

    let mut registry = AdapterRegistry::new(manifest.clone(),
                                            params.clone(), masks.clone());
    registry.register_file("tenant0", &path).unwrap();
    let (merged, served_masks) = registry.resolve("tenant0").unwrap();

    // the registry's merge must equal the in-memory mask_mul_add_scaled
    // merge exactly — same code path, bit-identical tensors
    let expected =
        lora::merge_manifest(&manifest, &params, &masks, &adapters)
            .unwrap();
    assert_eq!(merged.tensors, expected.tensors,
               "file round-trip changed the merged weights");
    // a merged store is dense (the merge destroys sparsity)
    assert!(served_masks.masks[0][0].data.iter().all(|&m| m == 1.0),
            "merged tenants must serve with dense masks");
    // ...and differs from the sparse base, since B was nonzero
    assert_ne!(merged.tensors, params.tensors);

    // merge-once caching: resolving again returns the same store
    let (again, _) = registry.resolve("tenant0").unwrap();
    assert!(std::sync::Arc::ptr_eq(&merged, &again));

    // the base tenant serves the sparse base unmodified
    let (base, base_masks) = registry.resolve(BASE_TENANT).unwrap();
    assert_eq!(base.tensors, params.tensors);
    assert_eq!(base_masks.masks, masks.masks);
}

#[test]
fn registry_and_adapter_io_validate_loudly() {
    let (session, dir) = open_session("validate");
    let manifest = session.manifest.clone();
    let params = ParamStore::from_init_bin(&manifest).unwrap();
    let masks = random_masks(&manifest, 1);
    let adapters = random_adapters(&manifest, 2);

    // wrong tensor count fails at export time
    let err = lora::save_adapters(&manifest, &adapters[1..], &dir.join("x"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("adapter export"), "{err}");

    // a non-adapter checkpoint fails at import with the path named
    let bogus = dir.join("bogus.ebft");
    ebft::model::checkpoint::save(
        &bogus, &[("not_an_adapter".to_string(), &adapters[0])]).unwrap();
    let err = lora::load_adapters(&manifest, &bogus)
        .unwrap_err()
        .to_string();
    assert!(err.contains("bogus.ebft"), "{err}");

    let mut registry = AdapterRegistry::new(manifest.clone(), params,
                                            masks);
    // the base tenant name is reserved
    let err = registry
        .register(BASE_TENANT, adapters.clone())
        .unwrap_err()
        .to_string();
    assert!(err.contains("reserved"), "{err}");
    // shape mismatches name the tenant
    let mut bad = adapters.clone();
    bad[0] = Tensor::zeros(&[1, 1]);
    let err = registry.register("t", bad).unwrap_err().to_string();
    assert!(err.contains("'t'") && err.contains("shape"), "{err}");
    // unknown tenants list what is registered
    registry.register("alpha", adapters).unwrap();
    let err = registry.resolve("nope").unwrap_err().to_string();
    assert!(err.contains("nope") && err.contains("alpha"), "{err}");
    assert_eq!(registry.tenants(), vec!["alpha".to_string()]);
}

/// Multi-tenant requests for the engine tests: round-robin over two
/// adapter tenants plus the shared base.
fn mixed_requests(n: usize, prompt_len: usize, max_new: usize,
                  deadline_ms: Option<f64>) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            id: i,
            tenant: match i % 3 {
                0 => BASE_TENANT.to_string(),
                1 => "alpha".to_string(),
                _ => "beta".to_string(),
            },
            prompt: (0..prompt_len)
                .map(|p| ((i * 7 + p * 3) % 32) as i32)
                .collect(),
            max_new,
            deadline_ms,
        })
        .collect()
}

fn engine_registry(session: &Session) -> AdapterRegistry {
    let manifest = session.manifest.clone();
    let params = ParamStore::from_init_bin(&manifest).unwrap();
    let masks = random_masks(&manifest, 0x5e);
    let mut registry = AdapterRegistry::new(manifest.clone(), params,
                                            masks);
    registry
        .register("alpha", random_adapters(&manifest, 10))
        .unwrap();
    registry
        .register("beta", random_adapters(&manifest, 11))
        .unwrap();
    registry
}

#[test]
fn continuous_batching_overlaps_and_matches_serial_exactly() {
    let (session, dir) = open_session("engine");
    let registry = engine_registry(&session);
    let requests = mixed_requests(6, 3, 6, None);

    let serial = serve(&dir, BackendKind::Reference, &registry,
                       requests.clone(),
                       &ServeConfig { workers: 1, max_batch: 1,
                                      ..ServeConfig::default() })
        .unwrap();
    let batched = serve(&dir, BackendKind::Reference, &registry, requests,
                        &ServeConfig { workers: 2, max_batch: 2,
                                       ..ServeConfig::default() })
        .unwrap();

    assert_eq!(serial.completions.len(), 6);
    assert_eq!(batched.completions.len(), 6);
    assert_eq!(serial.max_concurrent, 1);
    assert!(batched.max_concurrent >= 2,
            "2 workers × batch 2 over 6 requests must overlap, peak was \
             {}", batched.max_concurrent);
    assert!(batched.tokens_per_sec > 0.0);
    for (s, b) in serial.completions.iter().zip(&batched.completions) {
        assert_eq!(s.id, b.id);
        assert_eq!(s.tokens, b.tokens,
                   "request {}: batching changed the sampled tokens",
                   s.id);
        assert_eq!(s.finish, Finish::Length);
        assert_eq!(s.tokens.len(), 6);
    }
    assert_eq!(serial.total_new_tokens, 36);
    assert!(serial.p50_ms <= serial.p99_ms);
}

#[test]
fn top_k_sampling_is_scheduling_invariant_too() {
    let (session, dir) = open_session("topk");
    let registry = engine_registry(&session);
    let cfg = |workers, max_batch| ServeConfig {
        workers,
        max_batch,
        sampling: Sampling::TopK { k: 4, temperature: 0.9 },
        seed: 0xfeed,
        threads: 0,
    };
    let serial = serve(&dir, BackendKind::Reference, &registry,
                       mixed_requests(5, 2, 5, None), &cfg(1, 1))
        .unwrap();
    let batched = serve(&dir, BackendKind::Reference, &registry,
                        mixed_requests(5, 2, 5, None), &cfg(3, 2))
        .unwrap();
    for (s, b) in serial.completions.iter().zip(&batched.completions) {
        assert_eq!(s.tokens, b.tokens,
                   "request {}: per-request RNG streams must make \
                    sampling scheduling-invariant", s.id);
    }
}

#[test]
fn deadlines_cut_sequences_short() {
    let (session, dir) = open_session("deadline");
    let registry = engine_registry(&session);
    // a deadline already in the past: every sequence is cut at its
    // first tick, before sampling anything
    let report = serve(&dir, BackendKind::Reference, &registry,
                       mixed_requests(3, 2, 8, Some(0.0)),
                       &ServeConfig::default())
        .unwrap();
    for c in &report.completions {
        assert_eq!(c.finish, Finish::Deadline);
        assert!(c.tokens.is_empty());
    }
    assert_eq!(report.total_new_tokens, 0);
}

#[test]
fn cache_capacity_bounds_generation() {
    let (session, dir) = open_session("cachefull");
    let seq = session.manifest.dims.seq;
    let registry = engine_registry(&session);
    let prompt_len = 3;
    // a budget beyond the KV cache: generation stops at capacity
    let report = serve(&dir, BackendKind::Reference, &registry,
                       mixed_requests(2, prompt_len, seq * 2, None),
                       &ServeConfig::default())
        .unwrap();
    for c in &report.completions {
        assert_eq!(c.finish, Finish::CacheFull);
        assert_eq!(c.tokens.len(), seq - prompt_len + 1,
                   "one token per cache position, plus the final sample \
                    from the last position's logits");
    }
}

#[test]
fn serve_validates_requests_up_front() {
    let (session, dir) = open_session("validate-req");
    let registry = engine_registry(&session);
    let mut dup = mixed_requests(2, 2, 2, None);
    dup[1].id = dup[0].id;
    let err = serve(&dir, BackendKind::Reference, &registry, dup,
                    &ServeConfig::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("duplicate request id"), "{err}");

    let mut unknown = mixed_requests(1, 2, 2, None);
    unknown[0].tenant = "ghost".to_string();
    let err = serve(&dir, BackendKind::Reference, &registry, unknown,
                    &ServeConfig::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("ghost"), "{err}");

    // empty request set is a clean no-op
    let report = serve(&dir, BackendKind::Reference, &registry,
                       Vec::new(), &ServeConfig::default())
        .unwrap();
    assert!(report.completions.is_empty());
    assert_eq!(report.total_new_tokens, 0);
}
