//! Coordinator API contracts that need no artifacts: registry round-trips,
//! `PipelineBuilder` misuse, and `RunRecord` golden-JSON serialization.

use ebft::coordinator::{pruner, pruners, recoveries, recovery,
                        PipelineBuilder, RunRecord};
use ebft::ebft::finetune::{BlockReport, EbftReport};
use ebft::pruning::Pattern;
use ebft::tensor::MathTier;
use ebft::util::Json;

#[test]
fn registry_round_trips() {
    // every registered name (and alias) resolves back to the same method
    for p in pruners() {
        assert_eq!(pruner(p.name()).unwrap().name(), p.name());
        assert_eq!(pruner(p.name()).unwrap().label(), p.label());
        for a in p.aliases() {
            assert_eq!(pruner(a).unwrap().name(), p.name());
        }
    }
    for r in recoveries() {
        assert_eq!(recovery(r.name()).unwrap().name(), r.name());
        assert_eq!(recovery(r.name()).unwrap().label(), r.label());
        for a in r.aliases() {
            assert_eq!(recovery(a).unwrap().name(), r.name());
        }
    }
    // names are unique
    let mut names: Vec<&str> = pruners().iter().map(|p| p.name()).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), pruners().len());
}

#[test]
fn registry_rejects_unknown_names() {
    let err = pruner("not-a-method").unwrap_err();
    assert!(format!("{err:#}").contains("not-a-method"));
    assert!(format!("{err:#}").contains("wanda"),
            "error should list available methods: {err:#}");
    let err = recovery("not-a-recovery").unwrap_err();
    assert!(format!("{err:#}").contains("not-a-recovery"));
    assert!(format!("{err:#}").contains("ebft"),
            "error should list available recoveries: {err:#}");
}

#[test]
fn registry_covers_paper_methods() {
    for name in ["magnitude", "wanda", "sparsegpt", "flap"] {
        assert!(pruner(name).is_ok(), "missing pruner {name}");
    }
    for name in ["none", "dsnot", "ebft", "masktune", "lora"] {
        assert!(recovery(name).is_ok(), "missing recovery {name}");
    }
}

#[test]
fn builder_misuse_errors_not_panics() {
    // no stages at all → contextual error naming the missing stage
    let err = PipelineBuilder::new().build().unwrap_err();
    assert!(format!("{err:#}").contains("session"),
            "error should name the missing stage: {err:#}");
}

fn golden_record() -> RunRecord {
    RunRecord {
        pruner: "wanda".into(),
        pruner_label: "wanda".into(),
        pattern: Pattern::Unstructured(0.5),
        pattern_label: "50%".into(),
        recovery: "ebft".into(),
        recovery_label: "w.Ours".into(),
        ppl: 12.5,
        sparsity: 0.5,
        layer_sparsity: Vec::new(),
        prune_secs: 1.5,
        ft_secs: 2.25,
        eval_secs: 0.25,
        // 0 is elided from the JSON, so the golden bytes below still hold
        peak_resident_bytes: 0,
        // the defaults (exact tier, no recorded path) are elided too —
        // exact-tier records keep the pre-tier golden bytes
        math: MathTier::Exact,
        simd_path: String::new(),
        ebft_report: Some(EbftReport {
            per_block: vec![BlockReport {
                block: 0,
                epochs_run: 2,
                steps: 4,
                first_loss: 0.5,
                last_loss: 0.25,
                best_loss: 0.25,
                converged_early: true,
                secs: 1.5,
                bind_secs: 0.5,
            }],
            total_secs: 1.5,
        }),
    }
}

#[test]
fn run_record_golden_json() {
    let record = golden_record();
    assert_eq!(record.key(), "wanda/w.Ours/50%");
    let golden = concat!(
        r#"{"ebft":{"per_block":[{"best_loss":0.25,"bind_secs":0.5,"#,
        r#""block":0,"converged_early":true,"epochs":2,"first_loss":0.5,"#,
        r#""last_loss":0.25,"secs":1.5,"steps":4}],"total_secs":1.5},"#,
        r#""eval_secs":0.25,"ft_secs":2.25,"pattern":"50%","ppl":12.5,"#,
        r#""prune_secs":1.5,"pruner":"wanda","pruner_label":"wanda","#,
        r#""recovery":"ebft","recovery_label":"w.Ours","sparsity":0.5}"#,
    );
    assert_eq!(record.to_json().dump(), golden);
}

#[test]
fn run_record_json_round_trips() {
    let j = golden_record().to_json();
    let parsed = Json::parse(&j.dump()).unwrap();
    assert_eq!(parsed, j);
    // and a record without a report omits the ebft key entirely
    let mut bare = golden_record();
    bare.ebft_report = None;
    assert!(bare.to_json().opt("ebft").is_none());
    // per-layer sparsity is emitted only when tracked, and round-trips
    let mut layered = golden_record();
    assert!(layered.to_json().opt("layer_sparsity").is_none());
    layered.layer_sparsity = vec![0.5, 0.75];
    let lj = layered.to_json();
    assert!(lj.opt("layer_sparsity").is_some());
    assert_eq!(RunRecord::from_json(&lj).unwrap().to_json().dump(),
               lj.dump());
    // fast-tier records carry the tier + resolved dispatch path (the
    // perf-triage context), and round-trip byte-exactly
    let mut fast = golden_record();
    fast.math = MathTier::Fast;
    fast.simd_path = "avx2".into();
    let fj = fast.to_json();
    assert_eq!(fj.get("math").unwrap().as_str().unwrap(), "fast");
    assert_eq!(fj.get("simd_path").unwrap().as_str().unwrap(), "avx2");
    assert_eq!(RunRecord::from_json(&fj).unwrap().to_json().dump(),
               fj.dump());
}
