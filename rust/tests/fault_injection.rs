//! Crash/fault-injection harness for the run store, the lease protocol
//! and the checkpoint commit path.
//!
//! Each scenario spawns this same test binary as a child process
//! (`--exact fi_child_sweep`) with `EBFT_KILL_POINT` naming one of the
//! kill points compiled into the store/lease/checkpoint commit paths
//! (`util::faults::kill_point`). The child dies there with exit code 17
//! — no unwinding, exactly like `kill -9` landing between two syscalls —
//! and the harness then proves the contract:
//!
//! 1. a second, unkilled child *resumes* the same store and completes
//!    the sweep,
//! 2. the merged cell records are identical (modulo wall-clock timings)
//!    to a golden serial sweep that was never killed,
//! 3. no torn cell file is ever visible (every published `*.json`
//!    parses), and no `.claim.` / `.break.` lease debris survives
//!    recovery,
//! 4. a lease left behind by the dead holder is taken over once stale
//!    (the recovery run logs `lease-takeovers:`).
//!
//! The child is itself a `#[test]`, inert unless `EBFT_FI_CHILD` is set,
//! so a plain `cargo test` run never executes the sweep twice.

use ebft::config::FtConfig;
use ebft::coordinator::{Grid, RunRecord, RunStore, Scheduler, SweepEnv};
use ebft::data::{MarkovCorpus, Split};
use ebft::model::synth::{write_synthetic, SynthConfig};
use ebft::model::DenseModel;
use ebft::pretrain;
use ebft::pruning::Pattern;
use ebft::runtime::{BackendKind, Session};
use ebft::util::Json;
use std::path::{Path, PathBuf};
use std::process::Command;

/// Exit code `util::faults::kill_point` dies with (asserted, not
/// imported: the wire format is part of the contract under test).
const KILL_EXIT_CODE: i32 = 17;

const CHILD_VAR: &str = "EBFT_FI_CHILD";

fn base_dir() -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("ebft-fi-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ---------------------------------------------------------------------
// the child: one resumable single-worker sweep over a shared store
// ---------------------------------------------------------------------

/// Helper process body. Runs a small wanda sweep with `resume(true)`
/// against the store named by `EBFT_FI_STORE`; the pretrained teacher is
/// cached on disk so only the first child of the suite trains it.
#[test]
fn fi_child_sweep() {
    if std::env::var(CHILD_VAR).is_err() {
        return; // not spawned by the harness — inert under plain cargo test
    }
    let base = PathBuf::from(std::env::var("EBFT_FI_DIR").unwrap());
    let store_dir = PathBuf::from(std::env::var("EBFT_FI_STORE").unwrap());

    let synth = base.join("synth");
    let manifest = write_synthetic(&synth, &SynthConfig::tiny()).unwrap();
    let session =
        Session::open_kind(manifest, BackendKind::Reference).unwrap();
    let corpus = MarkovCorpus::new(session.manifest.dims.vocab, 7);
    let (dense, _) = pretrain::ensure_pretrained(
        &session, &corpus, &base.join("runs"), 40, 3e-3, 0).unwrap();
    let dense = DenseModel::resident(dense);

    let store = RunStore::open(&store_dir).unwrap();
    let grid = Grid::new(&["wanda"], &[Pattern::Unstructured(0.6)],
                         &["none", "dsnot"]).unwrap();
    let env = SweepEnv {
        artifact_dir: synth,
        corpus: &corpus,
        dense: &dense,
        ft: FtConfig { calib_seqs: 4, epochs: 2, ..FtConfig::default() },
        eval_seqs: 8,
        impl_name: "xla".to_string(),
        eval_split: Split::WikiSim,
        dense_tag: "fi-tiny".to_string(),
        backend: BackendKind::Reference,
        threads: 0,
        dtype: ebft::tensor::dtype::active_dtype(),
        math: ebft::tensor::kernels::math_tier(),
        max_resident_blocks: 0,
    };
    let out = Scheduler::new(env)
        .jobs(1)
        .resume(true)
        .store(&store)
        .local_session(&session)
        .run(&grid)
        .unwrap();
    println!("[fi-child] records={}", out.records.len());
    assert_eq!(out.records.len(), 2);
}

// ---------------------------------------------------------------------
// the harness
// ---------------------------------------------------------------------

fn spawn_child(store: &Path, kill: Option<&str>) -> std::process::Output {
    let exe = std::env::current_exe().unwrap();
    let mut cmd = Command::new(exe);
    cmd.args(["--exact", "fi_child_sweep", "--nocapture",
              "--test-threads=1"])
        .env(CHILD_VAR, "1")
        .env("EBFT_FI_DIR", base_dir())
        .env("EBFT_FI_STORE", store)
        // shrink the protocol clocks so stale-lease takeover happens in
        // tens of milliseconds, not tens of seconds
        .env("EBFT_LEASE_HEARTBEAT_MS", "10")
        .env("EBFT_LEASE_STALE_MS", "50")
        .env("EBFT_LEASE_POLL_MS", "20")
        .env_remove("EBFT_KILL_POINT");
    if let Some(point) = kill {
        cmd.env("EBFT_KILL_POINT", point);
    }
    cmd.output().unwrap()
}

fn stderr_of(out: &std::process::Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

/// Every *published* cell record under `store` (dot-prefixed staging
/// temps are invisible by construction). Panics on a torn file: a
/// half-written record that parses as neither JSON nor a RunRecord is
/// exactly the corruption the atomic-write protocol must rule out.
fn cell_records(store: &Path) -> Vec<RunRecord> {
    let mut records = Vec::new();
    for fp_entry in std::fs::read_dir(store).unwrap() {
        let cells = fp_entry.unwrap().path().join("cells");
        if !cells.is_dir() {
            continue;
        }
        for entry in std::fs::read_dir(&cells).unwrap() {
            let path = entry.unwrap().path();
            let name = path.file_name().unwrap().to_string_lossy()
                .into_owned();
            if !name.ends_with(".json") || name.starts_with('.') {
                continue;
            }
            let text = std::fs::read_to_string(&path).unwrap();
            let json = Json::parse(&text)
                .unwrap_or_else(|e| panic!("torn cell file {name}: {e:#}"));
            records.push(RunRecord::from_json(&json)
                .unwrap_or_else(|e| panic!("torn record {name}: {e:#}")));
        }
    }
    records
}

/// Record JSON with wall-clock and residency telemetry zeroed — the
/// "identical modulo timings" comparison, sorted for order independence.
fn normalized(mut records: Vec<RunRecord>) -> Vec<String> {
    let mut out: Vec<String> = records
        .iter_mut()
        .map(|r| {
            r.prune_secs = 0.0;
            r.ft_secs = 0.0;
            r.eval_secs = 0.0;
            r.peak_resident_bytes = 0;
            if let Some(rep) = &mut r.ebft_report {
                rep.total_secs = 0.0;
                for b in &mut rep.per_block {
                    b.secs = 0.0;
                    b.bind_secs = 0.0;
                }
            }
            r.to_json().dump()
        })
        .collect();
    out.sort();
    out
}

/// `.claim.` / `.break.` staging names must never survive: both are
/// removed on every exit path of `try_lease`, including takeover races.
fn assert_no_lease_debris(store: &Path, context: &str) {
    let mut stack = vec![store.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&dir) else { continue };
        for entry in entries {
            let path = entry.unwrap().path();
            if path.is_dir() {
                stack.push(path);
                continue;
            }
            let name = path.file_name().unwrap().to_string_lossy()
                .into_owned();
            assert!(
                !name.contains(".claim.") && !name.contains(".break."),
                "{context}: lease staging debris survived: {}",
                path.display());
        }
    }
}

/// Kill the child at `point`, then prove a fresh child resumes the
/// store to completion with records ≡ `golden`.
fn check_kill_point(point: &str, golden: &[String]) {
    let store = base_dir().join(point.replace('.', "-")).join("store");
    std::fs::create_dir_all(&store).unwrap();

    let killed = spawn_child(&store, Some(point));
    assert_eq!(killed.status.code(), Some(KILL_EXIT_CODE),
               "child was not killed at '{point}': status {:?}\n--- \
                stderr ---\n{}", killed.status, stderr_of(&killed));
    assert!(stderr_of(&killed).contains(&format!("killed at '{point}'")),
            "kill point '{point}' never fired");
    // whatever the crash left behind must already be readable: either a
    // complete record or nothing, never a torn file
    let partial = cell_records(&store);
    assert!(partial.len() < 2,
            "'{point}' fired after the sweep already finished");

    let resumed = spawn_child(&store, None);
    assert!(resumed.status.success(),
            "resume after '{point}' failed: status {:?}\n--- stderr ---\n{}",
            resumed.status, stderr_of(&resumed));
    assert_eq!(normalized(cell_records(&store)), golden,
               "records after crash-at-'{point}' + resume diverged from \
                the golden sweep");
    assert_no_lease_debris(&store, point);

    // a crash while *holding* a lease leaves the lease file behind with
    // a fresh heartbeat; the resumed run must have broken it once stale
    if point == "lease.after_claim" {
        let err = stderr_of(&resumed);
        assert!(err.contains("took over a stale lease"),
                "resume never took over the dead child's lease:\n{err}");
        assert!(err.contains("lease-takeovers:"),
                "scheduler did not report its takeover count:\n{err}");
    }
}

#[test]
fn kill_points_recover() {
    // golden serial sweep: never killed, same store layout
    let golden_store = base_dir().join("golden").join("store");
    std::fs::create_dir_all(&golden_store).unwrap();
    let out = spawn_child(&golden_store, None);
    assert!(out.status.success(),
            "golden sweep failed: status {:?}\n--- stderr ---\n{}",
            out.status, stderr_of(&out));
    let golden = normalized(cell_records(&golden_store));
    assert_eq!(golden.len(), 2, "golden sweep produced {golden:?}");
    assert_no_lease_debris(&golden_store, "golden");

    // every compiled kill point, ordered along the commit paths:
    // checkpoint body → lease lifecycle → record publish → rename
    for point in ["ckpt.after_params", "ckpt.after_masks",
                  "ckpt.after_meta", "lease.after_claim",
                  "lease.before_release", "record.before_write",
                  "record.after_write", "fsio.after_stage"] {
        eprintln!("--- kill point {point} ---");
        check_kill_point(point, &golden);
    }

    std::fs::remove_dir_all(base_dir()).ok();
}
