//! Integration: full coordinator pipelines driven through the
//! stage-based `Pipeline` API and the method registries.
//!
//! The suite runs twice:
//! - `pipeline_suite_reference` — always, in plain `cargo test`: a
//!   synthetic tiny manifest (`model::synth`) on the pure-Rust
//!   reference backend, no artifacts or Python toolchain needed;
//! - `pipeline_suite_pjrt` — the compiled-artifact variant on
//!   `artifacts/tiny`; requires `make artifacts` and skips otherwise.

use ebft::config::FtConfig;
use ebft::coordinator::{pruner, recovery, Grid, Pipeline, PipelineBuilder};
use ebft::data::{Batcher, MarkovCorpus, Split};
use ebft::masks::MaskSet;
use ebft::model::synth::{write_synthetic, SynthConfig};
use ebft::model::{DenseModel, ParamStore};
use ebft::pretrain;
use ebft::pruning::Pattern;
use ebft::runtime::{BackendKind, Session};
use std::path::Path;

struct Env {
    session: Session,
    corpus: MarkovCorpus,
    dense: DenseModel,
}

impl Env {
    /// The resident teacher store (these envs never stream).
    fn dense_store(&self) -> &ParamStore {
        self.dense.as_store().expect("test env teacher is resident")
    }
}

// Sessions are not Send (Rc + RefCell state), so the checks share one
// env on one thread: a single #[test] entry per backend runs every
// check in sequence.
fn build_env(kind: BackendKind) -> Option<Env> {
    let session = match kind {
        BackendKind::Pjrt => {
            let dir =
                Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
            if !dir.join("manifest.json").exists() {
                eprintln!("skipping: artifacts/tiny not built");
                return None;
            }
            Session::open_dir_kind(&dir, BackendKind::Pjrt).unwrap()
        }
        BackendKind::Reference => {
            let dir = std::env::temp_dir().join(format!(
                "ebft-pipeline-synth-{}", std::process::id()));
            let manifest =
                write_synthetic(&dir, &SynthConfig::tiny()).unwrap();
            Session::open_kind(manifest, BackendKind::Reference).unwrap()
        }
    };
    let corpus = MarkovCorpus::new(session.manifest.dims.vocab, 7);
    // short pretrain: enough for pruning damage to be measurable
    let (dense, _) =
        pretrain::pretrain(&session, &corpus, 150, 3e-3, 0, 50).unwrap();
    Some(Env { session, corpus, dense: DenseModel::resident(dense) })
}

fn run_suite(e: &Env) {
    let checks: Vec<(&str, fn(&Env))> = vec![
        ("every_pruner_hits_target_sparsity",
         every_pruner_hits_target_sparsity),
        ("nm_masks_validate", nm_masks_validate),
        ("ebft_improves_pruned_ppl", ebft_improves_pruned_ppl),
        ("ebft_report_is_consistent", ebft_report_is_consistent),
        ("masktune_and_dsnot_preserve_sparsity",
         masktune_and_dsnot_preserve_sparsity),
        ("grid_sweeps_with_checkpoint_reuse",
         grid_sweeps_with_checkpoint_reuse),
        ("flap_structured_and_recovery", flap_structured_and_recovery),
        ("lora_trains_and_merges", lora_trains_and_merges),
        ("zeroshot_suite_runs_on_sparse_model",
         zeroshot_suite_runs_on_sparse_model),
        ("pallas_impl_pipeline_matches_xla",
         pallas_impl_pipeline_matches_xla),
        ("fig2_monotone_tendency", fig2_monotone_tendency),
    ];
    for (name, check) in checks {
        let t0 = std::time::Instant::now();
        check(e);
        eprintln!("  check {name} ok ({:.1}s)", t0.elapsed().as_secs_f64());
    }
}

#[test]
fn pipeline_suite_reference() {
    let e = build_env(BackendKind::Reference)
        .expect("reference env needs no artifacts");
    run_suite(&e);
}

#[test]
fn pipeline_suite_pjrt() {
    let Some(e) = build_env(BackendKind::Pjrt) else { return };
    run_suite(&e);
}

fn test_ft() -> FtConfig {
    FtConfig { calib_seqs: 16, epochs: 6, ..FtConfig::default() }
}

fn pipeline(e: &Env) -> Pipeline<'_> {
    pipeline_with(e, test_ft())
}

fn pipeline_with(e: &Env, ft: FtConfig) -> Pipeline<'_> {
    PipelineBuilder::new()
        .session(&e.session)
        .corpus(&e.corpus)
        .dense(&e.dense)
        .ft(ft)
        .eval_seqs(32)
        .build()
        .unwrap()
}

fn every_pruner_hits_target_sparsity(e: &Env) {
    let pipe = pipeline(e);
    for name in ["magnitude", "wanda", "sparsegpt"] {
        let pruned = pipe
            .prune(pruner(name).unwrap(), Pattern::Unstructured(0.6))
            .unwrap();
        let s = pruned.masks.sparsity();
        assert!((s - 0.6).abs() < 0.02, "{name}: sparsity {s}");
        pruned.masks.validate_binary().unwrap();
        // weights at pruned positions must be irrelevant: eval works
        let ppl = ebft::eval::perplexity(&e.session, &pruned.params,
                                         &pruned.masks, &e.corpus,
                                         Split::WikiSim, 16)
            .unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
    }
}

fn nm_masks_validate(e: &Env) {
    let pipe = pipeline(e);
    for (n, m) in [(2usize, 4usize), (4, 8)] {
        let pruned = pipe
            .prune(pruner("wanda").unwrap(), Pattern::NM(n, m))
            .unwrap();
        pruned.masks.validate_nm(n, m).unwrap();
    }
}

fn ebft_improves_pruned_ppl(e: &Env) {
    let pipe = pipeline(e);
    let ckpt = pipe
        .prune(pruner("wanda").unwrap(), Pattern::Unstructured(0.7))
        .unwrap();
    let (_, _, raw) = pipe.recover(&ckpt, recovery("none").unwrap()).unwrap();
    let (_, _, tuned) =
        pipe.recover(&ckpt, recovery("ebft").unwrap()).unwrap();
    assert!(tuned.ppl < raw.ppl,
            "EBFT did not improve: {} → {}", raw.ppl, tuned.ppl);
    // sparsity must be preserved by fine-tuning
    assert!((tuned.sparsity - raw.sparsity).abs() < 1e-9);
}

fn ebft_report_is_consistent(e: &Env) {
    let pipe = pipeline(e);
    let cell = pipe
        .run_named("wanda", Pattern::Unstructured(0.5), "ebft")
        .unwrap();
    let report = cell.ebft_report.expect("ebft report");
    assert_eq!(report.per_block.len(), e.session.manifest.dims.n_layers);
    for b in &report.per_block {
        assert!(b.steps >= 1 && b.epochs_run >= 1);
        assert!(b.last_loss.is_finite());
        assert!(b.secs > 0.0);
        // residency uploads happen once per block, before the step loop,
        // and are a fraction of the block wall-clock
        assert!(b.bind_secs >= 0.0 && b.bind_secs <= b.secs,
                "bind_secs {} outside block secs {}", b.bind_secs, b.secs);
    }
    // the record carries labels resolved from the registries
    assert_eq!(cell.recovery_label, "w.Ours");
    assert_eq!(cell.pattern_label, "50%");
}

fn masktune_and_dsnot_preserve_sparsity(e: &Env) {
    let pipe = pipeline(e);
    let ckpt = pipe
        .prune(pruner("wanda").unwrap(), Pattern::Unstructured(0.6))
        .unwrap();
    let (_, _, raw) = pipe.recover(&ckpt, recovery("none").unwrap()).unwrap();
    for rec in ["dsnot", "masktune"] {
        let (_, _, cell) =
            pipe.recover(&ckpt, recovery(rec).unwrap()).unwrap();
        assert!((cell.sparsity - raw.sparsity).abs() < 1e-3,
                "{rec} changed sparsity {} → {}", raw.sparsity,
                cell.sparsity);
        assert!(cell.ppl.is_finite());
    }
}

fn grid_sweeps_with_checkpoint_reuse(e: &Env) {
    let pipe = pipeline(e);
    let grid = Grid::new(&["wanda"], &[Pattern::Unstructured(0.6)],
                         &["none", "dsnot"])
        .unwrap();
    assert_eq!(grid.n_cells(), 2);
    let swept = grid.run(&pipe).unwrap();
    assert_eq!(swept.records.len(), 2);
    let raw = swept.find("wanda", Pattern::Unstructured(0.6), "none")
        .expect("none cell");
    let ds = swept.find("wanda", Pattern::Unstructured(0.6), "dsnot")
        .expect("dsnot cell");
    assert!(raw.ppl.is_finite() && ds.ppl.is_finite());
    // both cells were recovered from the same pruned checkpoint
    assert!((raw.prune_secs - ds.prune_secs).abs() < 1e-12);
    // JSON export covers every cell
    assert_eq!(swept.to_json().as_obj().unwrap().len(), 2);
}

fn flap_structured_and_recovery(e: &Env) {
    let pipe = pipeline(e);
    let ckpt = pipe
        .prune(pruner("flap").unwrap(), Pattern::Structured(0.2))
        .unwrap();
    let s = ckpt.masks.sparsity();
    assert!(s > 0.08 && s < 0.4, "structured sparsity off target: {s}");
    // structured property: each pruned FFN channel zeroes full col+row
    // (validated indirectly by mask binary check + eval being finite)
    ckpt.masks.validate_binary().unwrap();
    let (params, masks, cell) =
        pipe.recover(&ckpt, recovery("ebft").unwrap()).unwrap();
    assert!(cell.ft_secs > 0.0);
    let ppl = ebft::eval::perplexity(&e.session, &params, &masks, &e.corpus,
                                     Split::WikiSim, 16).unwrap();
    assert!(ppl.is_finite());
}

fn lora_trains_and_merges(e: &Env) {
    let d = e.session.manifest.dims.clone();
    let calib = Batcher::new(&e.corpus, Split::InstructSim, 16, d.batch,
                             d.seq).ordered_batches();
    let masks = {
        let pipe = pipeline(e);
        pipe.prune(pruner("wanda").unwrap(), Pattern::Unstructured(0.5))
            .unwrap()
            .masks
    };
    let (adapters, report) = ebft::ebft::lora::train(
        &e.session, e.dense_store(), &masks, &calib, 30, 1e-2, 0).unwrap();
    assert!(report.last_loss < report.first_loss,
            "LoRA loss did not drop: {} → {}", report.first_loss,
            report.last_loss);
    let merged = ebft::ebft::lora::merge(&e.session, e.dense_store(), &masks,
                                         &adapters).unwrap();
    let dense_masks = MaskSet::dense(&e.session.manifest);
    let ppl = ebft::eval::perplexity(&e.session, &merged, &dense_masks,
                                     &e.corpus, Split::WikiSim, 16).unwrap();
    assert!(ppl.is_finite());
}

fn zeroshot_suite_runs_on_sparse_model(e: &Env) {
    let pipe = pipeline(e);
    let ckpt = pipe
        .prune(pruner("wanda").unwrap(), Pattern::Unstructured(0.5))
        .unwrap();
    let rec = pipe.recover_model(&ckpt, recovery("ebft").unwrap()).unwrap();
    let results = ebft::eval::run_suite(&e.session, &rec.params, &rec.masks,
                                        &e.corpus, 8, 3).unwrap();
    assert_eq!(results.len(), 7);
    for r in &results {
        assert!(r.n_items == 8);
        assert!(r.correct <= r.n_items);
    }
}

fn pallas_impl_pipeline_matches_xla(e: &Env) {
    // on PJRT this pins the Pallas kernel lowering against plain XLA; on
    // the reference backend the _pallas artifacts alias the base graphs,
    // so it degenerates to a determinism check of the whole cell
    let pipe_x = pipeline(e);
    let pipe_p = PipelineBuilder::new()
        .session(&e.session)
        .corpus(&e.corpus)
        .dense(&e.dense)
        .ft(test_ft())
        .eval_seqs(32)
        .impl_name("pallas")
        .build()
        .unwrap();
    let a = pipe_x
        .run_named("wanda", Pattern::Unstructured(0.5), "ebft")
        .unwrap();
    let b = pipe_p
        .run_named("wanda", Pattern::Unstructured(0.5), "ebft")
        .unwrap();
    let rel = ((a.ppl - b.ppl) / a.ppl).abs();
    assert!(rel < 0.02, "pallas vs xla pipeline ppl diverged: {} vs {}",
            a.ppl, b.ppl);
}

fn fig2_monotone_tendency(e: &Env) {
    // more calibration data should not make things (much) worse
    let mut ppls = Vec::new();
    for n in [8usize, 32] {
        let pipe = pipeline_with(e, FtConfig { calib_seqs: n, ..test_ft() });
        let cell = pipe
            .run_named("wanda", Pattern::Unstructured(0.7), "ebft")
            .unwrap();
        ppls.push(cell.ppl);
    }
    assert!(ppls[1] <= ppls[0] * 1.10,
            "32 samples much worse than 8: {ppls:?}");
}
