//! Integration: full coordinator pipelines on the `tiny` config.
//! Requires `make artifacts` (each test skips otherwise).

use ebft::config::FtConfig;
use ebft::coordinator::{Experiment, FtVariant};
use ebft::data::{Batcher, MarkovCorpus, Split};
use ebft::masks::MaskSet;
use ebft::model::ParamStore;
use ebft::pretrain;
use ebft::pruning::{self, Method, Pattern};
use ebft::runtime::Session;
use std::path::Path;

struct Env {
    session: Session,
    corpus: MarkovCorpus,
    dense: ParamStore,
}

// PJRT sessions are not Send (Rc + raw pointers), so the checks share one
// env on one thread: a single #[test] entry runs every check in sequence.
fn build_env() -> Option<Env> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny not built");
        return None;
    }
    let session = Session::open_dir(&dir).unwrap();
    let corpus = MarkovCorpus::new(session.manifest.dims.vocab, 7);
    // short pretrain: enough for pruning damage to be measurable
    let (dense, _) =
        pretrain::pretrain(&session, &corpus, 150, 3e-3, 0, 50).unwrap();
    Some(Env { session, corpus, dense })
}

#[test]
fn pipeline_suite() {
    let Some(e) = build_env() else { return };
    let checks: Vec<(&str, fn(&Env))> = vec![
        ("every_pruner_hits_target_sparsity",
         every_pruner_hits_target_sparsity),
        ("nm_masks_validate", nm_masks_validate),
        ("ebft_improves_pruned_ppl", ebft_improves_pruned_ppl),
        ("ebft_report_is_consistent", ebft_report_is_consistent),
        ("masktune_and_dsnot_preserve_sparsity",
         masktune_and_dsnot_preserve_sparsity),
        ("flap_structured_and_recovery", flap_structured_and_recovery),
        ("lora_trains_and_merges", lora_trains_and_merges),
        ("zeroshot_suite_runs_on_sparse_model",
         zeroshot_suite_runs_on_sparse_model),
        ("pallas_impl_pipeline_matches_xla",
         pallas_impl_pipeline_matches_xla),
        ("fig2_monotone_tendency", fig2_monotone_tendency),
    ];
    for (name, check) in checks {
        let t0 = std::time::Instant::now();
        check(&e);
        eprintln!("  check {name} ok ({:.1}s)", t0.elapsed().as_secs_f64());
    }
}

fn experiment(e: &Env) -> Experiment<'_> {
    Experiment {
        session: &e.session,
        corpus: &e.corpus,
        dense: &e.dense,
        ft: FtConfig { calib_seqs: 16, epochs: 6, ..FtConfig::default() },
        eval_seqs: 32,
        impl_name: "xla".into(),
    }
}

fn every_pruner_hits_target_sparsity(e: &Env) {
    let exp = experiment(e);
    let calib = exp.calib_batches();
    for method in [Method::Magnitude, Method::Wanda, Method::SparseGpt] {
        let mut params = e.dense.clone();
        let masks = pruning::prune_model(&e.session, &mut params, method,
                                         Pattern::Unstructured(0.6), &calib)
            .unwrap();
        let s = masks.sparsity();
        assert!((s - 0.6).abs() < 0.02, "{}: sparsity {s}", method.label());
        masks.validate_binary().unwrap();
        // weights at pruned positions must be irrelevant: eval works
        let ppl = ebft::eval::perplexity(&e.session, &params, &masks,
                                         &e.corpus, Split::WikiSim, 16)
            .unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
    }
}

fn nm_masks_validate(e: &Env) {
    let exp = experiment(e);
    let calib = exp.calib_batches();
    for (n, m) in [(2usize, 4usize), (4, 8)] {
        let mut params = e.dense.clone();
        let masks = pruning::prune_model(&e.session, &mut params,
                                         Method::Wanda, Pattern::NM(n, m),
                                         &calib).unwrap();
        masks.validate_nm(n, m).unwrap();
    }
}

fn ebft_improves_pruned_ppl(e: &Env) {
    let exp = experiment(e);
    let raw = exp.run_cell(Method::Wanda, Pattern::Unstructured(0.7),
                           FtVariant::None).unwrap();
    let tuned = exp.run_cell(Method::Wanda, Pattern::Unstructured(0.7),
                             FtVariant::Ebft).unwrap();
    assert!(tuned.ppl < raw.ppl,
            "EBFT did not improve: {} → {}", raw.ppl, tuned.ppl);
    // sparsity must be preserved by fine-tuning
    assert!((tuned.sparsity - raw.sparsity).abs() < 1e-9);
}

fn ebft_report_is_consistent(e: &Env) {
    let exp = experiment(e);
    let cell = exp.run_cell(Method::Wanda, Pattern::Unstructured(0.5),
                            FtVariant::Ebft).unwrap();
    let report = cell.ebft_report.expect("ebft report");
    assert_eq!(report.per_block.len(), e.session.manifest.dims.n_layers);
    for b in &report.per_block {
        assert!(b.steps >= 1 && b.epochs_run >= 1);
        assert!(b.last_loss.is_finite());
        assert!(b.secs > 0.0);
    }
}

fn masktune_and_dsnot_preserve_sparsity(e: &Env) {
    let exp = experiment(e);
    for variant in [FtVariant::Dsnot, FtVariant::MaskTune] {
        let raw = exp.run_cell(Method::Wanda, Pattern::Unstructured(0.6),
                               FtVariant::None).unwrap();
        let cell = exp.run_cell(Method::Wanda, Pattern::Unstructured(0.6),
                                variant).unwrap();
        assert!((cell.sparsity - raw.sparsity).abs() < 1e-3,
                "{:?} changed sparsity {} → {}", variant, raw.sparsity,
                cell.sparsity);
        assert!(cell.ppl.is_finite());
    }
}

fn flap_structured_and_recovery(e: &Env) {
    let exp = experiment(e);
    let calib = exp.calib_batches();
    let masks = pruning::flap::prune_model(&e.session, &e.dense, 0.2, &calib)
        .unwrap();
    let s = masks.sparsity();
    assert!(s > 0.08 && s < 0.4, "structured sparsity off target: {s}");
    // structured property: each pruned FFN channel zeroes full col+row
    // (validated indirectly by mask binary check + eval being finite)
    masks.validate_binary().unwrap();
    let (params, masks2, secs) = exp.run_structured(0.2, false, 0).unwrap();
    assert!(secs > 0.0);
    let ppl = ebft::eval::perplexity(&e.session, &params, &masks2, &e.corpus,
                                     Split::WikiSim, 16).unwrap();
    assert!(ppl.is_finite());
}

fn lora_trains_and_merges(e: &Env) {
    let d = e.session.manifest.dims.clone();
    let calib = Batcher::new(&e.corpus, Split::InstructSim, 16, d.batch,
                             d.seq).ordered_batches();
    let masks = {
        let exp = experiment(e);
        let c = exp.calib_batches();
        let mut p = e.dense.clone();
        pruning::prune_model(&e.session, &mut p, Method::Wanda,
                             Pattern::Unstructured(0.5), &c).unwrap()
    };
    let (adapters, report) = ebft::ebft::lora::train(
        &e.session, &e.dense, &masks, &calib, 30, 1e-2, 0).unwrap();
    assert!(report.last_loss < report.first_loss,
            "LoRA loss did not drop: {} → {}", report.first_loss,
            report.last_loss);
    let merged = ebft::ebft::lora::merge(&e.session, &e.dense, &masks,
                                         &adapters).unwrap();
    let dense_masks = MaskSet::dense(&e.session.manifest);
    let ppl = ebft::eval::perplexity(&e.session, &merged, &dense_masks,
                                     &e.corpus, Split::WikiSim, 16).unwrap();
    assert!(ppl.is_finite());
}

fn zeroshot_suite_runs_on_sparse_model(e: &Env) {
    let exp = experiment(e);
    let (params, masks) = exp.run_cell_model(Method::Wanda,
                                             Pattern::Unstructured(0.5),
                                             FtVariant::Ebft).unwrap();
    let results = ebft::eval::run_suite(&e.session, &params, &masks,
                                        &e.corpus, 8, 3).unwrap();
    assert_eq!(results.len(), 7);
    for r in &results {
        assert!(r.n_items == 8);
        assert!(r.correct <= r.n_items);
    }
}

fn pallas_impl_pipeline_matches_xla(e: &Env) {
    let exp_x = experiment(e);
    let mut exp_p = experiment(e);
    exp_p.impl_name = "pallas".into();
    let a = exp_x.run_cell(Method::Wanda, Pattern::Unstructured(0.5),
                           FtVariant::Ebft).unwrap();
    let b = exp_p.run_cell(Method::Wanda, Pattern::Unstructured(0.5),
                           FtVariant::Ebft).unwrap();
    let rel = ((a.ppl - b.ppl) / a.ppl).abs();
    assert!(rel < 0.02, "pallas vs xla pipeline ppl diverged: {} vs {}",
            a.ppl, b.ppl);
}

fn fig2_monotone_tendency(e: &Env) {
    // more calibration data should not make things (much) worse
    let mut ppls = Vec::new();
    for n in [8usize, 32] {
        let mut exp = experiment(e);
        exp.ft.calib_seqs = n;
        let cell = exp.run_cell(Method::Wanda, Pattern::Unstructured(0.7),
                                FtVariant::Ebft).unwrap();
        ppls.push(cell.ppl);
    }
    assert!(ppls[1] <= ppls[0] * 1.10,
            "32 samples much worse than 8: {ppls:?}");
}
