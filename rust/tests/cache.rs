//! Direct coverage for `ebft::cache::ActivationCache`: spill/reload
//! round-trips and budget accounting under realistic access patterns
//! (epoch-style sweeps, overwrites of spilled slots, stream advancement).
//! No artifacts needed — the cache is pure host+disk.

use ebft::ebft::cache::ActivationCache;
use ebft::tensor::Tensor;
use ebft::util::Pcg64;

const SHAPE: [usize; 3] = [2, 4, 8];
const BATCH_BYTES: usize = 2 * 4 * 8 * 4;

fn batch(seed: u64) -> Tensor {
    let mut rng = Pcg64::seeded(seed);
    Tensor::randn(&SHAPE, 1.0, &mut rng)
}

#[test]
fn epoch_sweeps_roundtrip_under_spill() {
    // 8 batches, budget for 3 resident: repeated full sweeps (the EBFT
    // epoch pattern) must keep returning bit-identical data while staying
    // under budget throughout
    let mut c = ActivationCache::new(8, &SHAPE, 3 * BATCH_BYTES, "it-sweep");
    for i in 0..8 {
        c.put(i, batch(i as u64)).unwrap();
        assert!(c.resident_bytes() <= 3 * BATCH_BYTES,
                "budget exceeded after put {i}: {}", c.resident_bytes());
    }
    for epoch in 0..3 {
        for i in 0..8 {
            assert_eq!(c.get(i).unwrap(), batch(i as u64),
                       "batch {i} corrupted (epoch {epoch})");
            assert!(c.resident_bytes() <= 3 * BATCH_BYTES);
        }
    }
    // every sweep over 8 batches with 3 resident must reload most of them
    assert!(c.reload_count >= 8, "reload_count {}", c.reload_count);
    assert!(c.spill_count >= 5, "spill_count {}", c.spill_count);
}

#[test]
fn overwrite_of_spilled_slot_returns_new_data() {
    // stream advancement overwrites every slot each block; a slot that
    // spilled under the old contents must serve the new contents
    let mut c = ActivationCache::new(4, &SHAPE, BATCH_BYTES, "it-ow");
    for i in 0..4 {
        c.put(i, batch(i as u64)).unwrap();
    }
    assert!(c.spill_count >= 3, "setup should have spilled");
    // slot 0 is spilled by now; overwrite it without reading first
    c.put(0, batch(100)).unwrap();
    assert_eq!(c.get(0).unwrap(), batch(100));
    // the other slots still round-trip
    for i in 1..4 {
        assert_eq!(c.get(i).unwrap(), batch(i as u64));
    }
}

#[test]
fn budget_accounting_counts_only_resident() {
    let mut c = ActivationCache::new(6, &SHAPE, 2 * BATCH_BYTES, "it-acct");
    assert_eq!(c.len(), 6);
    assert!(!c.is_empty());
    assert_eq!(c.resident_bytes(), 0, "empty cache holds no bytes");
    c.put(0, batch(0)).unwrap();
    assert_eq!(c.resident_bytes(), BATCH_BYTES);
    c.put(1, batch(1)).unwrap();
    assert_eq!(c.resident_bytes(), 2 * BATCH_BYTES);
    // third put evicts one: residency stays at the cap, not above
    c.put(2, batch(2)).unwrap();
    assert_eq!(c.resident_bytes(), 2 * BATCH_BYTES);
    assert_eq!(c.spill_count, 1);
    // a get of the spilled batch reloads it (and evicts another)
    let r0 = c.reload_count;
    assert_eq!(c.get(0).unwrap(), batch(0));
    assert_eq!(c.reload_count, r0 + 1);
    assert_eq!(c.resident_bytes(), 2 * BATCH_BYTES);
    // re-putting an already-resident slot must not double-count it
    c.put(0, batch(10)).unwrap();
    assert_eq!(c.get(0).unwrap(), batch(10));
    assert!(c.resident_bytes() <= 2 * BATCH_BYTES,
            "resident slot counted twice: {}", c.resident_bytes());
}

#[test]
fn generous_budget_never_touches_disk() {
    let mut c = ActivationCache::new(5, &SHAPE, 1 << 20, "it-mem");
    for i in 0..5 {
        c.put(i, batch(i as u64)).unwrap();
    }
    for _ in 0..2 {
        for i in 0..5 {
            assert_eq!(c.get(i).unwrap(), batch(i as u64));
        }
    }
    assert_eq!(c.spill_count, 0);
    assert_eq!(c.reload_count, 0);
    assert_eq!(c.resident_bytes(), 5 * BATCH_BYTES);
}

#[test]
fn two_caches_do_not_share_spill_files() {
    // teacher/student/targets streams coexist; tags must isolate them
    let mut a = ActivationCache::new(3, &SHAPE, BATCH_BYTES, "it-iso-a");
    let mut b = ActivationCache::new(3, &SHAPE, BATCH_BYTES, "it-iso-b");
    for i in 0..3 {
        a.put(i, batch(i as u64)).unwrap();
        b.put(i, batch(1000 + i as u64)).unwrap();
    }
    for i in 0..3 {
        assert_eq!(a.get(i).unwrap(), batch(i as u64));
        assert_eq!(b.get(i).unwrap(), batch(1000 + i as u64));
    }
}
