//! Out-of-core equivalence: a sweep whose dense teacher is streamed
//! block-by-block from the pretrain checkpoint (`--max-resident-blocks
//! 1`, the tightest budget) must produce byte-identical `RunRecord`s to
//! the fully-resident run — across intra-op thread counts and both
//! storage dtypes — while holding strictly less teacher memory.
//!
//! Runs entirely on the reference backend over the synthetic tiny
//! manifest (no artifacts), via `BenchEnv::open_synthetic_with` — the
//! same seam `ebft grid --synthetic --max-resident-blocks 1` exercises
//! from the CLI.

use ebft::bench_support::BenchEnv;
use ebft::config::FtConfig;
use ebft::coordinator::{Grid, GridResult, RunRecord, RunStore, Scheduler};
use ebft::pruning::Pattern;
use ebft::tensor::dtype::{self, Dtype};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("ebft-oo-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn test_ft() -> FtConfig {
    FtConfig { calib_seqs: 8, epochs: 2, ..FtConfig::default() }
}

/// One serial sweep of `grid` over `env` into a throwaway store, with an
/// explicit intra-op thread target.
fn sweep(env: &BenchEnv, grid: &Grid, threads: usize, tag: &str)
         -> GridResult {
    let dir = tmpdir(tag);
    let store = RunStore::open(&dir).unwrap();
    let mut senv = env.sweep_env(test_ft());
    senv.threads = threads;
    let out = Scheduler::new(senv)
        .jobs(1)
        .store(&store)
        .local_session(&env.session)
        .run(grid)
        .unwrap();
    std::fs::remove_dir_all(&dir).ok();
    out
}

/// Record JSON with wall-clock and residency telemetry zeroed: the
/// bit-identity claim is about every number the sweep computes, not
/// about how long or how much memory computing it took.
fn normalized(records: &[RunRecord]) -> Vec<String> {
    records
        .iter()
        .map(|r| {
            let mut r = r.clone();
            r.prune_secs = 0.0;
            r.ft_secs = 0.0;
            r.eval_secs = 0.0;
            r.peak_resident_bytes = 0;
            if let Some(rep) = &mut r.ebft_report {
                rep.total_secs = 0.0;
                for b in &mut rep.per_block {
                    b.secs = 0.0;
                    b.bind_secs = 0.0;
                }
            }
            r.to_json().dump()
        })
        .collect()
}

#[test]
fn streamed_teacher_matches_resident_across_threads_and_dtypes() {
    // ebft reads every teacher block per epoch; masktune streams them
    // once more through its own distillation pass — together they cover
    // both teacher-consuming recovery paths
    let grid = Grid::new(&["wanda"], &[Pattern::Unstructured(0.6)],
                         &["ebft", "masktune"]).unwrap();

    for dt in [Dtype::F32, Dtype::Bf16] {
        let prev = dtype::set_dtype(dt);

        // golden: fully-resident teacher, single-threaded kernels. The
        // resident env is opened first so a cold pretrain cache is
        // trained and saved under the dtype being tested.
        let resident_env = BenchEnv::open_synthetic_with(0).unwrap();
        let golden = sweep(&resident_env, &grid, 1, "golden");
        assert_eq!(golden.records.len(), 2);
        let resident_peak = resident_env.dense.peak_resident_bytes();
        assert!(resident_peak > 0);
        for r in &golden.records {
            assert_eq!(r.peak_resident_bytes, resident_peak,
                       "resident records must report the full store size");
        }

        for threads in [1usize, 2, 8] {
            // fresh streamed env per setting: the block cache's
            // high-water mark starts at zero every time
            let env = BenchEnv::open_synthetic_with(1).unwrap();
            assert!(env.dense.is_streamed());
            let out = sweep(&env, &grid, threads, "streamed");
            assert_eq!(
                normalized(&out.records), normalized(&golden.records),
                "streamed ({dt:?}, {threads} threads) diverged from the \
                 resident golden run");
            for (s, g) in out.records.iter().zip(&golden.records) {
                assert!(s.peak_resident_bytes > 0,
                        "streamed run never tracked residency");
                assert!(
                    s.peak_resident_bytes < g.peak_resident_bytes,
                    "streamed {} peak {} not strictly below resident {}",
                    s.key(), s.peak_resident_bytes, g.peak_resident_bytes);
            }
        }

        dtype::set_dtype(prev);
    }
}
