//! Property-based tests on coordinator invariants (generative, seeded by
//! our own PCG64 — no external proptest crate in this offline environment,
//! so each property runs against a few hundred random cases and prints the
//! failing seed on assertion).

use ebft::data::{Batcher, MarkovCorpus, Split};
use ebft::ebft::cache::ActivationCache;
use ebft::masks::{mask_from_nm, mask_from_topk, mask_from_topk_per_col};
use ebft::model::checkpoint;
use ebft::tensor::{linalg, Tensor};
use ebft::util::{Json, Pcg64};
use std::collections::HashMap;

const CASES: usize = 120;

fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f32() < 0.5),
        2 => {
            // round-trippable doubles: small rationals
            let v = (rng.next_f64() * 2e6).round() / 64.0 - 1e4;
            Json::Num(v)
        }
        3 => {
            let len = rng.below(12) as usize;
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(128) as u8;
                    if c.is_ascii_graphic() || c == b' ' {
                        c as char
                    } else {
                        match c % 4 {
                            0 => '\n',
                            1 => '"',
                            2 => '\\',
                            _ => '\u{e9}',
                        }
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => {
            let len = rng.below(5) as usize;
            Json::Arr((0..len).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let len = rng.below(5) as usize;
            let mut obj = Json::obj();
            for i in 0..len {
                let key = format!("k{}_{}", i, rng.below(1000));
                obj.set(&key, random_json(rng, depth - 1));
            }
            obj
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    for seed in 0..CASES as u64 {
        let mut rng = Pcg64::seeded(seed);
        let j = random_json(&mut rng, 3);
        let text = j.dump();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: parse failed: {e}\n{text}"));
        assert_eq!(j, back, "seed {seed}: roundtrip mismatch\n{text}");
    }
}

#[test]
fn prop_checkpoint_roundtrip() {
    let dir = std::env::temp_dir();
    for seed in 0..40u64 {
        let mut rng = Pcg64::seeded(1000 + seed);
        let n = 1 + rng.below(6) as usize;
        let tensors: Vec<(String, Tensor)> = (0..n)
            .map(|i| {
                let rank = rng.below(3) as usize + 1;
                let shape: Vec<usize> =
                    (0..rank).map(|_| 1 + rng.below(8) as usize).collect();
                (format!("t{i}"), Tensor::randn(&shape, 1.0, &mut rng))
            })
            .collect();
        let path = dir.join(format!("ebft-prop-{}-{seed}.ebft",
                                    std::process::id()));
        let refs: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        checkpoint::save(&path, &refs).unwrap();
        let loaded = checkpoint::load(&path).unwrap();
        assert_eq!(loaded.len(), tensors.len());
        for ((n1, t1), (n2, t2)) in tensors.iter().zip(&loaded) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2, "seed {seed}");
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn prop_cache_matches_reference_model() {
    // random put/get traffic under random budgets must behave exactly like
    // a plain HashMap (spilling is transparent)
    for seed in 0..30u64 {
        let mut rng = Pcg64::seeded(2000 + seed);
        let n = 2 + rng.below(6) as usize;
        let shape = [1 + rng.below(3) as usize, 4];
        let numel: usize = shape.iter().product();
        let budget = (numel * 4) * (1 + rng.below(n as u64) as usize);
        let mut cache = ActivationCache::new(n, &shape, budget,
                                             &format!("prop{seed}"));
        let mut reference: HashMap<usize, Tensor> = HashMap::new();
        for _op in 0..60 {
            let idx = rng.below(n as u64) as usize;
            if rng.next_f32() < 0.5 {
                let t = Tensor::randn(&shape, 1.0, &mut rng);
                cache.put(idx, t.clone()).unwrap();
                reference.insert(idx, t);
            } else if let Some(want) = reference.get(&idx) {
                let got = cache.get(idx).unwrap();
                assert_eq!(&got, want, "seed {seed} idx {idx}");
            }
        }
        // final sweep
        for (idx, want) in &reference {
            assert_eq!(&cache.get(*idx).unwrap(), want, "seed {seed} final");
        }
    }
}

#[test]
fn prop_topk_masks_exact_counts() {
    for seed in 0..CASES as u64 {
        let mut rng = Pcg64::seeded(3000 + seed);
        let rows = 1 + rng.below(40) as usize;
        let cols = 1 + rng.below(20) as usize;
        let scores = Tensor::randn(&[rows, cols], 1.0, &mut rng);
        let k_total = rng.below((rows * cols) as u64 + 1) as usize;
        let m = mask_from_topk(&scores, k_total);
        assert_eq!(m.count_nonzero(), k_total, "seed {seed}");

        let k_col = rng.below(rows as u64 + 1) as usize;
        let mc = mask_from_topk_per_col(&scores, k_col).unwrap();
        for c in 0..cols {
            let kept = (0..rows).filter(|&r| mc.at2(r, c) != 0.0).count();
            assert_eq!(kept, k_col, "seed {seed} col {c}");
        }
    }
}

#[test]
fn prop_nm_masks_valid_for_random_group_sizes() {
    for seed in 0..CASES as u64 {
        let mut rng = Pcg64::seeded(4000 + seed);
        let m_group = [2usize, 4, 8][rng.below(3) as usize];
        let n_keep = 1 + rng.below(m_group as u64) as usize;
        let rows = m_group * (1 + rng.below(8) as usize);
        let cols = 1 + rng.below(12) as usize;
        let scores = Tensor::randn(&[rows, cols], 1.0, &mut rng);
        let mask = mask_from_nm(&scores, n_keep, m_group).unwrap();
        for c in 0..cols {
            for g in (0..rows).step_by(m_group) {
                let kept = (g..g + m_group)
                    .filter(|&r| mask.at2(r, c) != 0.0)
                    .count();
                assert_eq!(kept, n_keep, "seed {seed}");
            }
        }
    }
}

#[test]
fn prop_cholesky_reconstructs_random_spd() {
    for seed in 0..40u64 {
        let mut rng = Pcg64::seeded(5000 + seed);
        let n = 1 + rng.below(24) as usize;
        let b = Tensor::randn(&[n, n], 1.0, &mut rng);
        let mut a = b.transpose2().unwrap().matmul(&b).unwrap();
        linalg::add_damping(&mut a, 0.1 + n as f32);
        let l = linalg::cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose2().unwrap()).unwrap();
        let rel = a.sub(&rec).max_abs() / a.max_abs();
        assert!(rel < 1e-4, "seed {seed} rel {rel}");
        // inverse property
        let inv = linalg::spd_inverse(&a).unwrap();
        let prod = a.matmul(&inv).unwrap();
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at2(i, j) - want).abs() < 5e-3,
                        "seed {seed} ({i},{j})");
            }
        }
    }
}

#[test]
fn prop_batcher_epochs_are_permutations() {
    let corpus = MarkovCorpus::new(64, 11);
    for seed in 0..20u64 {
        let mut rng = Pcg64::seeded(6000 + seed);
        let batch = 1 + rng.below(4) as usize;
        let n_batches = 1 + rng.below(5) as usize;
        let n_seqs = batch * n_batches;
        let b = Batcher::new(&corpus, Split::Calib, n_seqs, batch, 8);
        for epoch in 0..3u64 {
            let rows: Vec<Vec<i32>> = b
                .epoch(epoch)
                .into_iter()
                .flat_map(|bt| bt.chunks_exact(8)
                    .map(|c| c.to_vec()).collect::<Vec<_>>())
                .collect();
            assert_eq!(rows.len(), n_seqs);
            // each expected sequence appears exactly once
            let mut expected: Vec<Vec<i32>> = (0..n_seqs as u64)
                .map(|i| corpus.sequence(Split::Calib, i, 8))
                .collect();
            let mut got = rows.clone();
            expected.sort();
            got.sort();
            assert_eq!(expected, got, "seed {seed} epoch {epoch}");
        }
    }
}

#[test]
fn prop_dsnot_reselect_invariants() {
    for seed in 0..60u64 {
        let mut rng = Pcg64::seeded(7000 + seed);
        let rows = 4 + rng.below(28) as usize;
        let cols = 1 + rng.below(8) as usize;
        let w = Tensor::randn(&[rows, cols], 1.0, &mut rng);
        let means = Tensor::randn(&[rows], 1.0, &mut rng);
        let norms = means.map(f32::abs);
        let density = 0.2 + 0.6 * rng.next_f32();
        let k = ((rows * cols) as f32 * density) as usize;
        let mask = mask_from_topk(&w.map(f32::abs), k);
        let before_count = mask.count_nonzero();
        let (new_mask, _swaps) =
            ebft::dsnot::reselect(&w, &mask, &means, &norms, 20).unwrap();
        assert_eq!(new_mask.count_nonzero(), before_count, "seed {seed}");
        assert!(new_mask.data.iter().all(|&x| x == 0.0 || x == 1.0));
        // per-column |err| must not increase
        for c in 0..cols {
            let err = |m: &Tensor| -> f64 {
                (0..rows)
                    .filter(|&r| m.at2(r, c) == 0.0)
                    .map(|r| -(w.at2(r, c) * means.data[r]) as f64)
                    .sum()
            };
            assert!(err(&new_mask).abs() <= err(&mask).abs() + 1e-6,
                    "seed {seed} col {c}");
        }
    }
}

#[test]
fn prop_sparsegpt_sparsity_and_finiteness() {
    for seed in 0..25u64 {
        let mut rng = Pcg64::seeded(8000 + seed);
        let rows = 8 * (1 + rng.below(6) as usize);
        let cols = 1 + rng.below(12) as usize;
        let w = Tensor::randn(&[rows, cols], 1.0, &mut rng);
        let x = Tensor::randn(&[rows * 2, rows], 1.0, &mut rng);
        let gram = x.transpose2().unwrap().matmul(&x).unwrap();
        let s = [0.25f32, 0.5, 0.75][rng.below(3) as usize];
        let (mask, new_w) = ebft::pruning::sparsegpt::prune(
            &w, &gram, ebft::pruning::Pattern::Unstructured(s)).unwrap();
        let got = 1.0 - mask.count_nonzero() as f64 / mask.numel() as f64;
        assert!((got - s as f64).abs() < 0.06, "seed {seed} s={s} got={got}");
        assert!(new_w.data.iter().all(|v| v.is_finite()), "seed {seed}");
        for (wv, mv) in new_w.data.iter().zip(&mask.data) {
            if *mv == 0.0 {
                assert_eq!(*wv, 0.0, "seed {seed}");
            }
        }
    }
}

#[test]
fn prop_zero_shot_items_always_well_formed() {
    let corpus = MarkovCorpus::new(128, 13);
    for seed in 0..20u64 {
        for task in ebft::data::zeroshot::ALL_TASKS {
            for item in task.items(&corpus, 6, 48, seed) {
                assert!(item.correct < item.choices.len());
                let len0 = item.choices[0].len();
                for ch in &item.choices {
                    assert_eq!(ch.len(), len0);
                    assert!(item.prompt.len() + ch.len() <= 48);
                    assert!(ch.iter().all(|&t| (0..128).contains(&t)));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// lease state machine
// ---------------------------------------------------------------------

use ebft::coordinator::{Lease, LeaseConfig, LeaseOutcome, RunStore};

struct Holder {
    lease: Lease,
    /// Instant of the last *successful* heartbeat (or the claim).
    beat: u64,
    /// Set once another claim has provably broken this lease: every
    /// later heartbeat from the old holder must fail.
    zombie: bool,
}

/// Arbitrary interleavings of claim / heartbeat / release / clock-stall
/// over 2–4 simulated workers hammering one real `RunStore` lease file,
/// with time injected through the `*_at` seams.
///
/// Safety: a claim never succeeds while another worker holds the lease
/// with a fresh heartbeat (the never-double-execute invariant); once it
/// does succeed, the previous holder's heartbeats fail forever.
/// Liveness: whatever state an interleaving ends in, the lease is
/// claimable after one stale interval (the sweep always drains).
#[test]
fn prop_lease_never_double_held_and_always_drains() {
    let dir = std::env::temp_dir()
        .join(format!("ebft-prop-lease-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let store = RunStore::open(&dir).unwrap();
    let cfg = LeaseConfig { heartbeat_ms: 10, stale_ms: 100, poll_ms: 10 };

    for seed in 0..CASES as u64 {
        let mut rng = Pcg64::seeded(9000 + seed);
        let fp = format!("leasefp{seed}");
        let key = "wanda/w.Ours/60%";
        let n_workers = 2 + rng.below(3) as usize;
        let mut workers: Vec<Option<Holder>> =
            (0..n_workers).map(|_| None).collect();
        let mut now: u64 = cfg.stale_ms; // past the epoch: beat 0 is stale
        let mut acquires = 0usize;

        for step in 0..60 {
            now += rng.below(40);
            let w = rng.below(n_workers as u64) as usize;
            match rng.below(4) {
                0 => {
                    // claim
                    let outcome = store
                        .try_lease_at(&fp, key, &cfg, now)
                        .unwrap();
                    if let LeaseOutcome::Acquired { lease, took_over } =
                        outcome
                    {
                        let mut live_stale = false;
                        for (i, slot) in workers.iter_mut().enumerate() {
                            let Some(h) = slot else { continue };
                            if i == w || h.zombie {
                                continue;
                            }
                            assert!(
                                now.saturating_sub(h.beat) >= cfg.stale_ms,
                                "seed {seed} step {step}: worker {w} \
                                 acquired while worker {i} held a fresh \
                                 lease (beat {} now {now})", h.beat);
                            live_stale = true;
                        }
                        if live_stale {
                            assert!(took_over,
                                    "seed {seed} step {step}: broke a \
                                     tracked stale lease without \
                                     reporting a takeover");
                        }
                        // every other holder (incl. w's own old lease)
                        // is dead from here on
                        for (i, slot) in
                            workers.iter_mut().enumerate()
                        {
                            if let Some(h) = slot {
                                if i != w || h.lease.token != lease.token {
                                    h.zombie = true;
                                }
                            }
                        }
                        workers[w] = Some(Holder {
                            lease,
                            beat: now,
                            zombie: false,
                        });
                        acquires += 1;
                    }
                }
                1 => {
                    // heartbeat
                    let Some(h) = &mut workers[w] else { continue };
                    let ok =
                        store.heartbeat_at(&h.lease, now).unwrap();
                    if h.zombie {
                        assert!(!ok,
                                "seed {seed} step {step}: a broken \
                                 lease's heartbeat succeeded");
                        workers[w] = None;
                    } else {
                        assert!(ok,
                                "seed {seed} step {step}: a live \
                                 holder's heartbeat failed");
                        h.beat = now;
                    }
                }
                2 => {
                    // release (a no-op on a lease broken away)
                    if let Some(h) = workers[w].take() {
                        store.release(&h.lease).unwrap();
                    }
                }
                _ => {
                    // clock stall: the current holder (if any) stops
                    // heartbeating for a full stale interval
                    now += cfg.stale_ms;
                }
            }
        }

        // liveness: one stale interval after the last event, a fresh
        // worker always gets the lease
        now += cfg.stale_ms;
        let outcome = store.try_lease_at(&fp, key, &cfg, now).unwrap();
        let LeaseOutcome::Acquired { lease, .. } = outcome else {
            panic!("seed {seed}: lease not claimable after a stale \
                    interval ({acquires} acquires during the run)");
        };
        store.release(&lease).unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}
