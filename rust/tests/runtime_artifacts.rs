//! Integration: the AOT bridge end-to-end — manifest → PJRT → numerics.
//!
//! Requires `make artifacts` (skips otherwise). Uses the `tiny` config.

use ebft::masks::MaskSet;
use ebft::model::{Manifest, ParamStore};
use ebft::runtime::{Session, Value};
use ebft::tensor::Tensor;
use ebft::util::Pcg64;
use std::path::Path;

fn open_tiny() -> Option<(Session, ParamStore)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/tiny not built");
        return None;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let params = ParamStore::from_init_bin(&manifest).unwrap();
    Some((Session::open(manifest).unwrap(), params))
}

fn dense_block_inputs<'a>(params: &'a ParamStore, session: &Session,
                          masks: &'a MaskSet, l: usize) -> Vec<Value<'a>> {
    let mut inputs: Vec<Value> = params
        .block_params(&session.manifest, l)
        .into_iter()
        .map(Value::F32)
        .collect();
    for m in masks.block(l) {
        inputs.push(Value::F32(m));
    }
    inputs
}

fn random_tokens(session: &Session, seed: u64) -> Vec<i32> {
    let d = &session.manifest.dims;
    let mut rng = Pcg64::seeded(seed);
    (0..d.batch * d.seq)
        .map(|_| rng.below(d.vocab as u64) as i32)
        .collect()
}

#[test]
fn decomposed_chain_matches_monolithic_lm_loss() {
    let Some((session, params)) = open_tiny() else { return };
    let d = session.manifest.dims.clone();
    let masks = MaskSet::dense(&session.manifest);
    let tokens = random_tokens(&session, 1);
    let tok_shape = [d.batch, d.seq];

    // decomposed: embed → blocks → head
    let x0 = session
        .run("embed_fwd", &[
            Value::F32(params.get("embed").unwrap()),
            Value::I32(&tok_shape, &tokens),
        ])
        .unwrap()
        .remove(0);
    let mut x = x0;
    for l in 0..d.n_layers {
        let mut inputs = dense_block_inputs(&params, &session, &masks, l);
        inputs.push(Value::F32(&x));
        x = session.run("block_fwd", &inputs).unwrap().remove(0);
    }
    let out = session
        .run("head_loss", &[
            Value::F32(params.get("final.norm.g").unwrap()),
            Value::F32(params.get("final.head").unwrap()),
            Value::F32(&x),
            Value::I32(&tok_shape, &tokens),
        ])
        .unwrap();
    let decomposed = out[0].item() / out[1].item();

    // monolithic lm_loss
    let mut inputs: Vec<Value> =
        params.tensors.iter().map(Value::F32).collect();
    for l in 0..d.n_layers {
        for m in masks.block(l) {
            inputs.push(Value::F32(m));
        }
    }
    inputs.push(Value::I32(&tok_shape, &tokens));
    let mono = session.run("lm_loss", &inputs).unwrap()[0].item();

    assert!((decomposed - mono).abs() < 1e-4,
            "decomposed {decomposed} vs monolithic {mono}");
    // sanity: near ln(vocab) for random init
    assert!((mono - (d.vocab as f32).ln()).abs() < 1.0);
}

#[test]
fn block_ft_step_converges_on_recoverable_target() {
    let Some((session, params)) = open_tiny() else { return };
    let d = session.manifest.dims.clone();
    let masks = MaskSet::dense(&session.manifest);
    let mut rng = Pcg64::seeded(7);
    let x = Tensor::randn(&[d.batch, d.seq, d.d_model], 1.0, &mut rng);

    // target: the same block's dense output (recoverable exactly)
    let mut inputs = dense_block_inputs(&params, &session, &masks, 0);
    inputs.push(Value::F32(&x));
    let target = session.run("block_fwd", &inputs).unwrap().remove(0);

    // perturb the weights, then fine-tune back
    let mut bp: Vec<Tensor> = params
        .block_params(&session.manifest, 0)
        .into_iter()
        .cloned()
        .collect();
    for t in bp.iter_mut().take(7) {
        let noise = Tensor::randn(&t.shape, 0.05, &mut rng);
        *t = t.add(&noise);
    }
    let mut m_st: Vec<Tensor> =
        bp.iter().map(|t| Tensor::zeros(&t.shape)).collect();
    let mut v_st = m_st.clone();

    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    for step in 1..=40 {
        let mut ins: Vec<Value> = bp.iter().map(Value::F32).collect();
        for m in masks.block(0) {
            ins.push(Value::F32(m));
        }
        for t in &m_st {
            ins.push(Value::F32(t));
        }
        for t in &v_st {
            ins.push(Value::F32(t));
        }
        ins.push(Value::Scalar(step as f32));
        ins.push(Value::Scalar(5e-3));
        ins.push(Value::F32(&x));
        ins.push(Value::F32(&target));
        let mut outs = session.run("block_ft_step", &ins).unwrap();
        let loss = outs.pop().unwrap().item();
        if step == 1 {
            first_loss = loss;
        }
        last_loss = loss;
        v_st = outs.split_off(18);
        m_st = outs.split_off(9);
        bp = outs;
    }
    assert!(last_loss < first_loss * 0.2,
            "no convergence: first {first_loss} last {last_loss}");
}

#[test]
fn pallas_and_xla_block_fwd_agree() {
    let Some((session, params)) = open_tiny() else { return };
    let d = session.manifest.dims.clone();
    let masks = MaskSet::dense(&session.manifest);
    let mut rng = Pcg64::seeded(9);
    let x = Tensor::randn(&[d.batch, d.seq, d.d_model], 1.0, &mut rng);

    let mut inputs = dense_block_inputs(&params, &session, &masks, 1);
    inputs.push(Value::F32(&x));
    let y_xla = session.run("block_fwd", &inputs).unwrap().remove(0);

    let mut inputs = dense_block_inputs(&params, &session, &masks, 1);
    inputs.push(Value::F32(&x));
    let y_pallas = session.run("block_fwd_pallas", &inputs).unwrap().remove(0);

    let diff = y_xla.sub(&y_pallas).max_abs();
    assert!(diff < 1e-3, "pallas vs xla block_fwd diff {diff}");
}

#[test]
fn masked_weights_do_not_affect_output() {
    // zeroing a pruned weight's value must not change block output
    let Some((session, params)) = open_tiny() else { return };
    let d = session.manifest.dims.clone();
    let mut rng = Pcg64::seeded(11);
    let x = Tensor::randn(&[d.batch, d.seq, d.d_model], 1.0, &mut rng);

    let mut masks = MaskSet::dense(&session.manifest);
    // prune half of wq
    let shape = masks.masks[0][0].shape.clone();
    let scores = Tensor::randn(&shape, 1.0, &mut rng);
    masks.masks[0][0] =
        ebft::masks::mask_from_topk(&scores, shape.iter().product::<usize>() / 2);

    let mut inputs = dense_block_inputs(&params, &session, &masks, 0);
    inputs.push(Value::F32(&x));
    let y1 = session.run("block_fwd", &inputs).unwrap().remove(0);

    // scramble pruned positions of wq; output must be identical
    let mut bp: Vec<Tensor> = params
        .block_params(&session.manifest, 0)
        .into_iter()
        .cloned()
        .collect();
    let m = &masks.masks[0][0];
    for (w, &mk) in bp[0].data.iter_mut().zip(&m.data) {
        if mk == 0.0 {
            *w = 999.0;
        }
    }
    let mut inputs: Vec<Value> = bp.iter().map(Value::F32).collect();
    for m in masks.block(0) {
        inputs.push(Value::F32(m));
    }
    inputs.push(Value::F32(&x));
    let y2 = session.run("block_fwd", &inputs).unwrap().remove(0);

    assert_eq!(y1.data, y2.data);
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some((session, params)) = open_tiny() else { return };
    let bad = Tensor::ones(&[1, 2, 3]);
    let err = session.run("embed_fwd", &[
        Value::F32(params.get("embed").unwrap()),
        Value::F32(&bad),
    ]);
    assert!(err.is_err());
    let err2 = session.run("embed_fwd", &[Value::F32(&bad)]);
    assert!(err2.is_err());
}
