//! Integration: the artifact set end-to-end — manifest → backend →
//! numerics, through the typed Plan / DeviceBuffer API.
//!
//! Runs twice per check: on the reference backend over a synthetic
//! manifest (always, plain `cargo test`) and on PJRT over
//! `artifacts/tiny` (requires `make artifacts`, skips otherwise).

use ebft::masks::MaskSet;
use ebft::model::synth::{write_synthetic, SynthConfig};
use ebft::model::{Manifest, ParamStore};
use ebft::runtime::{BackendKind, DeviceBuffer, Plan, Session};
use ebft::tensor::Tensor;
use ebft::util::Pcg64;
use std::path::Path;

// tests run on parallel threads, so every reference test generates into
// its own directory (same synthetic config, so same model everywhere)
fn open_env(kind: BackendKind, tag: &str) -> Option<(Session, ParamStore)> {
    let manifest = match kind {
        BackendKind::Pjrt => {
            let dir =
                Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
            if !dir.join("manifest.json").exists() {
                eprintln!("skipping: artifacts/tiny not built");
                return None;
            }
            Manifest::load(&dir).unwrap()
        }
        BackendKind::Reference => {
            let dir = std::env::temp_dir().join(format!(
                "ebft-rta-{tag}-{}", std::process::id()));
            write_synthetic(&dir, &SynthConfig::tiny()).unwrap()
        }
    };
    let params = ParamStore::from_init_bin(&manifest).unwrap();
    Some((Session::open_kind(manifest, kind).unwrap(), params))
}

/// Bind block `l`'s params and masks to a block-artifact plan.
fn bind_block(plan: &mut Plan<'_>, params: &ParamStore, session: &Session,
              masks: &MaskSet, l: usize) {
    plan.bind_indexed("bp", params.block_params(&session.manifest, l))
        .unwrap();
    plan.bind_indexed("mask", masks.block(l).iter()).unwrap();
}

fn random_tokens(session: &Session, seed: u64) -> Vec<i32> {
    let d = &session.manifest.dims;
    let mut rng = Pcg64::seeded(seed);
    (0..d.batch * d.seq)
        .map(|_| rng.below(d.vocab as u64) as i32)
        .collect()
}

fn check_decomposed_chain_matches_monolithic_lm_loss(session: &Session,
                                                     params: &ParamStore) {
    let d = session.manifest.dims.clone();
    let masks = MaskSet::dense(&session.manifest);
    let tokens = random_tokens(session, 1);

    // decomposed: embed → blocks → head, activations runtime-resident
    let mut embed = session.plan("embed_fwd").unwrap();
    embed.bind_tensor("embed", params.get("embed").unwrap()).unwrap();
    embed.bind_tokens("tokens", &tokens).unwrap();
    let mut x = embed.run_to_device().unwrap().remove(0);
    for l in 0..d.n_layers {
        let mut fwd = session.plan("block_fwd").unwrap();
        bind_block(&mut fwd, params, session, &masks, l);
        fwd.bind("x", &x).unwrap();
        x = fwd.run_to_device().unwrap().remove(0);
    }
    let mut head = session.plan("head_loss").unwrap();
    head.bind_tensor("g_norm", params.get("final.norm.g").unwrap()).unwrap();
    head.bind_tensor("head", params.get("final.head").unwrap()).unwrap();
    head.bind("x", &x).unwrap();
    head.bind_tokens("tokens", &tokens).unwrap();
    let out = head.run().unwrap();
    let decomposed = out[0].item() / out[1].item();

    // monolithic lm_loss, params + masks bound once
    let mut mono_plan = session.plan("lm_loss").unwrap();
    mono_plan.bind_indexed("param", params.tensors.iter()).unwrap();
    let flat = (0..d.n_layers).flat_map(|l| masks.block(l).iter());
    mono_plan.bind_indexed("mask", flat).unwrap();
    mono_plan.bind_tokens("tokens", &tokens).unwrap();
    let mono = mono_plan.run().unwrap()[0].item();

    assert!((decomposed - mono).abs() < 1e-4,
            "decomposed {decomposed} vs monolithic {mono}");
    // sanity: near ln(vocab) for random init
    assert!((mono - (d.vocab as f32).ln()).abs() < 1.0);
}

#[test]
fn decomposed_chain_matches_monolithic_lm_loss_reference() {
    let (session, params) = open_env(BackendKind::Reference, "chain").unwrap();
    check_decomposed_chain_matches_monolithic_lm_loss(&session, &params);
}

#[test]
fn decomposed_chain_matches_monolithic_lm_loss_pjrt() {
    let Some((session, params)) = open_env(BackendKind::Pjrt, "pjrt") else {
        return;
    };
    check_decomposed_chain_matches_monolithic_lm_loss(&session, &params);
}

fn check_block_ft_step_converges(session: &Session, params: &ParamStore) {
    let d = session.manifest.dims.clone();
    let masks = MaskSet::dense(&session.manifest);
    let mut rng = Pcg64::seeded(7);
    let x = Tensor::randn(&[d.batch, d.seq, d.d_model], 1.0, &mut rng);

    // target: the same block's dense output (recoverable exactly)
    let mut fwd = session.plan("block_fwd").unwrap();
    bind_block(&mut fwd, params, session, &masks, 0);
    fwd.bind_tensor("x", &x).unwrap();
    let target = fwd.run_to_device().unwrap().remove(0);

    // perturb the weights, then fine-tune back
    let mut bp: Vec<Tensor> = params
        .block_params(&session.manifest, 0)
        .into_iter()
        .cloned()
        .collect();
    for t in bp.iter_mut().take(7) {
        let noise = Tensor::randn(&t.shape, 0.05, &mut rng);
        *t = t.add(&noise);
    }

    let mut ft = session.plan("block_ft_step").unwrap();
    ft.bind_indexed("bp", bp.iter()).unwrap();
    ft.bind_indexed("mask", masks.block(0).iter()).unwrap();
    for (j, t) in bp.iter().enumerate() {
        let z = DeviceBuffer::zeros(&t.shape).unwrap();
        ft.bind(&format!("m.{j}"), &z).unwrap();
        ft.bind(&format!("v.{j}"), &z).unwrap();
    }
    // weights + Adam state circulate runtime-resident
    assert_eq!(ft.donate_matching().unwrap(), 27);
    ft.bind_scalar("lr", 5e-3).unwrap();
    ft.bind("x", &x).unwrap();
    ft.bind("target", &target).unwrap();
    let loss_out = ft.output_index("loss").unwrap();

    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    for step in 1..=40 {
        ft.bind_scalar("t", step as f32).unwrap();
        let outs = ft.run_to_device().unwrap();
        let loss = outs[loss_out].fetch_scalar().unwrap();
        if step == 1 {
            first_loss = loss;
        }
        last_loss = loss;
    }
    assert!(last_loss < first_loss * 0.2,
            "no convergence: first {first_loss} last {last_loss}");

    // the donated weights stayed bound: fetching them gives tensors that
    // differ from the perturbed start (training actually moved them)
    let w0 = ft.bound("bp.0").unwrap().fetch().unwrap();
    assert!(w0.sub(&bp[0]).max_abs() > 0.0,
            "donated weights never updated");
}

#[test]
fn block_ft_step_converges_with_donated_state_reference() {
    let (session, params) =
        open_env(BackendKind::Reference, "ftconv").unwrap();
    check_block_ft_step_converges(&session, &params);
}

#[test]
fn block_ft_step_converges_with_donated_state_pjrt() {
    let Some((session, params)) = open_env(BackendKind::Pjrt, "pjrt") else {
        return;
    };
    check_block_ft_step_converges(&session, &params);
}

fn check_pallas_and_xla_block_fwd_agree(session: &Session,
                                        params: &ParamStore) {
    // on PJRT this pins the Pallas kernel artifacts against plain XLA;
    // the reference backend aliases the two, so it checks the alias
    let d = session.manifest.dims.clone();
    let masks = MaskSet::dense(&session.manifest);
    let mut rng = Pcg64::seeded(9);
    let x = Tensor::randn(&[d.batch, d.seq, d.d_model], 1.0, &mut rng);

    let run_fwd = |name: &str| -> Tensor {
        let mut plan = session.plan(name).unwrap();
        bind_block(&mut plan, params, session, &masks,
                   d.n_layers.min(2) - 1);
        plan.bind_tensor("x", &x).unwrap();
        plan.run().unwrap().remove(0)
    };
    let y_xla = run_fwd("block_fwd");
    let y_pallas = run_fwd("block_fwd_pallas");

    let diff = y_xla.sub(&y_pallas).max_abs();
    assert!(diff < 1e-3, "pallas vs xla block_fwd diff {diff}");
}

#[test]
fn pallas_and_xla_block_fwd_agree_reference() {
    let (session, params) =
        open_env(BackendKind::Reference, "pallas").unwrap();
    check_pallas_and_xla_block_fwd_agree(&session, &params);
}

#[test]
fn pallas_and_xla_block_fwd_agree_pjrt() {
    let Some((session, params)) = open_env(BackendKind::Pjrt, "pjrt") else {
        return;
    };
    check_pallas_and_xla_block_fwd_agree(&session, &params);
}

fn check_masked_weights_do_not_affect_output(session: &Session,
                                             params: &ParamStore) {
    // zeroing a pruned weight's value must not change block output
    let d = session.manifest.dims.clone();
    let mut rng = Pcg64::seeded(11);
    let x = Tensor::randn(&[d.batch, d.seq, d.d_model], 1.0, &mut rng);

    let mut masks = MaskSet::dense(&session.manifest);
    // prune half of wq
    let shape = masks.masks[0][0].shape.clone();
    let scores = Tensor::randn(&shape, 1.0, &mut rng);
    masks.masks[0][0] = ebft::masks::mask_from_topk(
        &scores, shape.iter().product::<usize>() / 2);

    let mut plan = session.plan("block_fwd").unwrap();
    bind_block(&mut plan, params, session, &masks, 0);
    plan.bind_tensor("x", &x).unwrap();
    let y1 = plan.run().unwrap().remove(0);

    // scramble pruned positions of wq; output must be identical
    let mut bp: Vec<Tensor> = params
        .block_params(&session.manifest, 0)
        .into_iter()
        .cloned()
        .collect();
    let m = &masks.masks[0][0];
    for (w, &mk) in bp[0].data.iter_mut().zip(&m.data) {
        if mk == 0.0 {
            *w = 999.0;
        }
    }
    plan.bind_indexed("bp", bp.iter()).unwrap();
    let y2 = plan.run().unwrap().remove(0);

    assert_eq!(y1.data, y2.data);
}

#[test]
fn masked_weights_do_not_affect_output_reference() {
    let (session, params) =
        open_env(BackendKind::Reference, "masked").unwrap();
    check_masked_weights_do_not_affect_output(&session, &params);
}

#[test]
fn masked_weights_do_not_affect_output_pjrt() {
    let Some((session, params)) = open_env(BackendKind::Pjrt, "pjrt") else {
        return;
    };
    check_masked_weights_do_not_affect_output(&session, &params);
}

fn check_persistent_bindings_survive_across_runs(session: &Session,
                                                 params: &ParamStore) {
    // the same plan executes repeatedly with only the stream slot rebound;
    // results match fresh single-shot plans
    let masks = MaskSet::dense(&session.manifest);

    let mut plan = session.plan("block_fwd").unwrap();
    bind_block(&mut plan, params, session, &masks, 0);
    let d = session.manifest.dims.clone();
    let mut rng = Pcg64::seeded(13);
    for _ in 0..3 {
        let x = Tensor::randn(&[d.batch, d.seq, d.d_model], 1.0, &mut rng);
        plan.bind_tensor("x", &x).unwrap();
        let y_reused = plan.run().unwrap().remove(0);

        let mut fresh = session.plan("block_fwd").unwrap();
        bind_block(&mut fresh, params, session, &masks, 0);
        fresh.bind_tensor("x", &x).unwrap();
        let y_fresh = fresh.run().unwrap().remove(0);
        assert_eq!(y_reused.data, y_fresh.data);
    }
}

#[test]
fn persistent_bindings_survive_across_runs_reference() {
    let (session, params) =
        open_env(BackendKind::Reference, "persist").unwrap();
    check_persistent_bindings_survive_across_runs(&session, &params);
}

#[test]
fn persistent_bindings_survive_across_runs_pjrt() {
    let Some((session, params)) = open_env(BackendKind::Pjrt, "pjrt") else {
        return;
    };
    check_persistent_bindings_survive_across_runs(&session, &params);
}
