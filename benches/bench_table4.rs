//! Table 4: fine-tuning cost and quality at 20 % structured sparsity
//! (FLAP masks): LoRA on the big instruct split vs EBFT on 64 calibration
//! sequences. The paper's headline cost claim — EBFT ≈ 10× cheaper wall
//! clock at equal-or-better perplexity — plus the per-block timing report
//! (§4: "50–60 s per block, ~30 min total" at Llama-7B scale).

use ebft::bench_support::BenchEnv;
use ebft::config::FtConfig;
use ebft::data::Split;
use ebft::eval;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};

/// LoRA steps sized to mimic "2 epochs over a 50k-row dataset" at testbed
/// scale: ~25× the number of EBFT optimizer steps.
const LORA_STEPS: usize = 800;

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open(0)?;
    let exp = env.experiment();
    let dense_ppl = exp.dense_ppl()?;
    println!("dense ppl {}", fmt_ppl(dense_ppl));

    let mut table = TableWriter::new(
        "Table 4 — LoRA vs EBFT at 20% structured (FLAP)",
        &["method", "sparsity", "time(s)", "perplexity"]);
    let mut results = Json::obj();

    // --- LoRA ---
    let (lora_params, lora_masks, lora_secs) =
        exp.run_structured(0.20, true, LORA_STEPS)?;
    let lora_ppl = eval::perplexity(&env.session, &lora_params, &lora_masks,
                                    &env.corpus, Split::WikiSim, 64)?;
    table.row(&["LoRA".into(), "20%".into(), format!("{lora_secs:.1}"),
                fmt_ppl(lora_ppl)]);

    // --- EBFT (with per-block timing, the §4 cost table) ---
    let (ebft_params, ebft_masks, ebft_secs) =
        exp.run_structured(0.20, false, 0)?;
    let ebft_ppl = eval::perplexity(&env.session, &ebft_params, &ebft_masks,
                                    &env.corpus, Split::WikiSim, 64)?;
    table.row(&["Ours".into(), "20%".into(), format!("{ebft_secs:.1}"),
                fmt_ppl(ebft_ppl)]);
    table.print();

    // per-block timing detail (run finetune directly for the report)
    let calib = exp.calib_batches();
    let masks = ebft::pruning::flap::prune_model(&env.session, &env.dense,
                                                 0.20, &calib)?;
    let mut params = env.dense.clone();
    let report = ebft::ebft::finetune(&env.session, &env.dense, &mut params,
                                      &masks, &FtConfig::default(), &calib,
                                      "xla")?;
    println!("per-block fine-tuning cost (the paper's 50–60 s/block story):");
    for b in &report.per_block {
        println!("  block {}: {:.2}s  ({} steps, loss {:.4} → {:.4}{})",
                 b.block, b.secs, b.steps, b.first_loss, b.last_loss,
                 if b.converged_early { ", early-stop" } else { "" });
    }
    println!("  total {:.1}s, mean {:.2}s/block", report.total_secs,
             report.mean_block_secs());

    let speedup = lora_secs / ebft_secs.max(1e-9);
    println!("EBFT speedup over LoRA: {speedup:.1}×  \
              (paper reports ~10× at Llama-7B scale)");

    results.set("dense_ppl", Json::Num(dense_ppl));
    results.set("lora_ppl", Json::Num(lora_ppl));
    results.set("lora_secs", Json::Num(lora_secs));
    results.set("ebft_ppl", Json::Num(ebft_ppl));
    results.set("ebft_secs", Json::Num(ebft_secs));
    results.set("speedup", Json::Num(speedup));
    results.set("mean_block_secs", Json::Num(report.mean_block_secs()));
    env.write_json("table4", &results)?;
    Ok(())
}
