//! Table 4: fine-tuning cost and quality at 20 % structured sparsity
//! (FLAP masks): LoRA on the big instruct split vs EBFT on 64 calibration
//! sequences. The paper's headline cost claim — EBFT ≈ 10× cheaper wall
//! clock at equal-or-better perplexity — plus the per-block timing report
//! (§4: "50–60 s per block, ~30 min total" at Llama-7B scale).
//! EBFT_JOBS=2 runs the two recoveries concurrently off one FLAP prune.

use ebft::bench_support::BenchEnv;
use ebft::config::FtConfig;
use ebft::coordinator::Grid;
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};

/// LoRA steps sized to mimic "2 epochs over a 50k-row dataset" at testbed
/// scale: ~25× the number of EBFT optimizer steps.
const LORA_STEPS: usize = 800;

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open(0)?;
    let ft = FtConfig { lora_steps: LORA_STEPS, ..FtConfig::default() };
    let pipe = env.pipeline_with(ft.clone())?;
    let dense_ppl = pipe.dense_ppl()?;
    println!("dense ppl {}", fmt_ppl(dense_ppl));

    let mut table = TableWriter::new(
        "Table 4 — LoRA vs EBFT at 20% structured (FLAP)",
        &["method", "sparsity", "time(s)", "perplexity"]);
    let mut results = Json::obj();

    // FLAP once; both recoveries share the pruned checkpoint, and run
    // concurrently under EBFT_JOBS=2 (the scheduler's DAG: one prune job
    // feeding two recovery jobs)
    let pattern = Pattern::Structured(0.20);
    let grid = Grid::new(&["flap"], &[pattern], &["lora", "ebft"])?;
    let swept = env.run_grid_with(&grid, ft)?;
    let lora = swept.find("flap", pattern, "lora").expect("lora cell");
    let ours = swept.find("flap", pattern, "ebft").expect("ebft cell");

    table.row(&["LoRA".into(), "20%".into(), format!("{:.1}", lora.ft_secs),
                fmt_ppl(lora.ppl)]);
    table.row(&["Ours".into(), "20%".into(), format!("{:.1}", ours.ft_secs),
                fmt_ppl(ours.ppl)]);
    table.print();

    // per-block timing detail from the EBFT recovery's own report
    let report = ours.ebft_report.as_ref().expect("ebft recovery report");
    println!("per-block fine-tuning cost (the paper's 50–60 s/block story):");
    for b in &report.per_block {
        println!("  block {}: {:.2}s  ({} steps, loss {:.4} → {:.4}{})",
                 b.block, b.secs, b.steps, b.first_loss, b.last_loss,
                 if b.converged_early { ", early-stop" } else { "" });
    }
    println!("  total {:.1}s, mean {:.2}s/block", report.total_secs,
             report.mean_block_secs());

    let speedup = lora.ft_secs / ours.ft_secs.max(1e-9);
    println!("EBFT speedup over LoRA: {speedup:.1}×  \
              (paper reports ~10× at Llama-7B scale)");

    results.set("dense_ppl", Json::Num(dense_ppl));
    results.set("lora_ppl", Json::Num(lora.ppl));
    results.set("lora_secs", Json::Num(lora.ft_secs));
    results.set("ebft_ppl", Json::Num(ours.ppl));
    results.set("ebft_secs", Json::Num(ours.ft_secs));
    results.set("speedup", Json::Num(speedup));
    results.set("mean_block_secs", Json::Num(report.mean_block_secs()));
    env.write_json("table4", &results)?;
    Ok(())
}
