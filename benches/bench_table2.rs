//! Table 2: perplexity at N:M semi-structured sparsity (2:4 and 4:8),
//! methods {magnitude, wanda, sparsegpt} × {raw, DSnoT, EBFT}.
//! EBFT_JOBS=N for concurrent cells, EBFT_RESUME=1 to resume (see
//! bench_support).

use ebft::bench_support::{model_indices, BenchEnv};
use ebft::coordinator::{recovery, Grid};
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};

fn main() -> anyhow::Result<()> {
    let patterns = [Pattern::NM(2, 4), Pattern::NM(4, 8)];
    let methods = ["magnitude", "wanda", "sparsegpt"];
    let recoveries = ["none", "dsnot", "ebft"];

    let mut results = Json::obj();
    for model_idx in model_indices() {
        let env = BenchEnv::open(model_idx)?;
        println!("=== {} ===", env.label);

        let grid = Grid::new(&methods, &patterns, &recoveries)?;
        let swept = env.run_grid(&grid)?;

        let mut table = TableWriter::new(
            &format!("Table 2 — {} N:M", env.label),
            &["method", "2:4", "4:8"]);
        let mut model_json = Json::obj();
        for method in methods {
            for rec in recoveries {
                let rec_label = recovery(rec)?.label();
                let row_label = if rec == "none" {
                    method.to_string()
                } else {
                    format!("  {rec_label}")
                };
                let mut cells = vec![row_label];
                for pattern in patterns {
                    let cell = swept
                        .find(method, pattern, rec)
                        .expect("grid cell missing");
                    cells.push(fmt_ppl(cell.ppl));
                    model_json.set(
                        &format!("{method}/{rec_label}/{}",
                                 pattern.label()),
                        Json::Num(cell.ppl));
                }
                table.row(&cells);
            }
        }
        table.print();
        results.set(&env.label.clone(), model_json);
        env.write_json("table2", &results)?;
    }
    Ok(())
}
