//! Table 2: perplexity at N:M semi-structured sparsity (2:4 and 4:8),
//! methods {magnitude, wanda, sparsegpt} × {raw, DSnoT, EBFT}.

use ebft::bench_support::{model_indices, BenchEnv};
use ebft::coordinator::FtVariant;
use ebft::pruning::{Method, Pattern};
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};

fn main() -> anyhow::Result<()> {
    let patterns = [Pattern::NM(2, 4), Pattern::NM(4, 8)];
    let methods = [Method::Magnitude, Method::Wanda, Method::SparseGpt];
    let variants = [FtVariant::None, FtVariant::Dsnot, FtVariant::Ebft];

    let mut results = Json::obj();
    for model_idx in model_indices() {
        let env = BenchEnv::open(model_idx)?;
        let exp = env.experiment();
        println!("=== {} ===", env.label);
        let mut table = TableWriter::new(
            &format!("Table 2 — {} N:M", env.label),
            &["method", "2:4", "4:8"]);
        let mut model_json = Json::obj();
        for method in methods {
            for variant in variants {
                let row_label = match variant {
                    FtVariant::None => method.label().to_string(),
                    v => format!("  {}", v.label()),
                };
                let mut cells = vec![row_label];
                for pattern in patterns {
                    let cell = exp.run_cell(method, pattern, variant)?;
                    cells.push(fmt_ppl(cell.ppl));
                    model_json.set(
                        &format!("{}/{}/{}", method.label(),
                                 variant.label(), pattern.label()),
                        Json::Num(cell.ppl));
                }
                table.row(&cells);
            }
        }
        table.print();
        results.set(&env.label.clone(), model_json);
        env.write_json("table2", &results)?;
    }
    Ok(())
}
