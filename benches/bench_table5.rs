//! Table 5: LoRA vs EBFT across structured parameter budgets, with both
//! perplexity and the zero-shot suite — the paper's 5.5B/5.0B rows map to
//! removing ~13 % / ~26 % of prunable parameters here.
//!
//! Default grid: MiniLlama-A; EBFT_FULL=1 adds MiniLlama-B.
//!
//! Like bench_table3, the zero-shot metric keeps this bench outside the
//! RunRecord sweep path, so it uses the run store at checkpoint
//! granularity: a killed run re-launches without re-pruning FLAP.

use ebft::bench_support::{model_indices, BenchEnv};
use ebft::config::FtConfig;
use ebft::coordinator::{pruner, recovery};
use ebft::eval::zeroshot::{mean_accuracy, run_suite};
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};

const LORA_STEPS: usize = 400;
const ITEMS: usize = 24;

fn main() -> anyhow::Result<()> {
    let budgets = [0.13f32, 0.26];
    let mut results = Json::obj();
    for model_idx in model_indices() {
        let env = BenchEnv::open(model_idx)?;
        let ft = FtConfig { lora_steps: LORA_STEPS, ..FtConfig::default() };
        let pipe = env.pipeline_with(ft.clone())?;
        let store = env.store()?;
        let fingerprint = env.fingerprint(&ft);
        println!("=== {} ===", env.label);
        let mut table = TableWriter::new(
            &format!("Table 5 — {} LoRA vs EBFT (structured budgets)",
                     env.label),
            &["budget", "method", "zero-shot mean", "wiki ppl"]);
        for &budget in &budgets {
            let pattern = Pattern::Structured(budget);
            let pruned = pipe.prune_cached(&store, &fingerprint,
                                           pruner("flap")?, pattern)?;
            for (rec, name) in [("lora", "LoRA"), ("ebft", "Ours")] {
                let (params, masks, record) =
                    pipe.recover(&pruned, recovery(rec)?)?;
                let ppl = record.ppl;
                let zs = run_suite(&env.session, &params, &masks, &env.corpus,
                                   ITEMS, 3)?;
                let mean = mean_accuracy(&zs);
                table.row(&[format!("-{}%", (budget * 100.0) as u32),
                            name.into(), format!("{mean:.2}"),
                            fmt_ppl(ppl)]);
                results.set(&format!("{}/{}/{}", env.label,
                                     (budget * 100.0) as u32, name),
                            Json::parse(&format!(
                                r#"{{"ppl": {ppl}, "zs_mean": {mean}}}"#))?);
            }
            store.remove_checkpoint(&fingerprint, "flap", pattern)?;
        }
        table.print();
        env.write_json("table5", &results)?;
    }
    Ok(())
}
