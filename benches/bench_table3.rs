//! Table 3: zero-shot suite accuracy at 60 % unstructured and 2:4 sparsity,
//! methods {magnitude, wanda, sparsegpt} × {raw, DSnoT, EBFT}.
//!
//! Default grid: 60 % only; EBFT_FULL=1 adds the 2:4 pattern.
//!
//! Zero-shot cells fall outside RunRecord sweeps, so this bench uses the
//! run store at checkpoint granularity: pruned checkpoints persist under
//! runs/store/ while their recoveries run, so an interrupted sweep
//! re-launches without re-pruning (the checkpoint is dropped once every
//! recovery of the group has been measured).

use ebft::bench_support::{full_grid, model_indices, BenchEnv};
use ebft::config::FtConfig;
use ebft::coordinator::{pruner, recovery};
use ebft::eval::zeroshot::{mean_accuracy, run_suite};
use ebft::pruning::Pattern;
use ebft::util::{Json, TableWriter};

const ITEMS: usize = 32;

fn main() -> anyhow::Result<()> {
    let patterns: Vec<Pattern> = if full_grid() {
        vec![Pattern::Unstructured(0.6), Pattern::NM(2, 4)]
    } else {
        vec![Pattern::Unstructured(0.6)]
    };
    let methods = ["magnitude", "wanda", "sparsegpt"];
    let recoveries = ["none", "dsnot", "ebft"];

    let mut results = Json::obj();
    for model_idx in model_indices() {
        let env = BenchEnv::open(model_idx)?;
        let pipe = env.pipeline()?;
        let store = env.store()?;
        let fingerprint = env.fingerprint(&FtConfig::default());
        for &pattern in &patterns {
            println!("=== {} @ {} ===", env.label, pattern.label());
            let mut headers: Vec<String> =
                vec!["method".into()];
            // task names from a probe run on the dense model
            let dense_masks = ebft::masks::MaskSet::dense(&env.session.manifest);
            let probe = run_suite(&env.session, env.dense_params()?,
                                  &dense_masks, &env.corpus, 2, 3)?;
            headers.extend(probe.iter().map(|r| r.task.to_string()));
            headers.push("Mean".into());
            let hdr_refs: Vec<&str> =
                headers.iter().map(|s| s.as_str()).collect();
            let mut table = TableWriter::new(
                &format!("Table 3 — {} @ {}", env.label, pattern.label()),
                &hdr_refs);

            // dense reference row
            let dense_res = run_suite(&env.session, env.dense_params()?,
                                      &dense_masks, &env.corpus, ITEMS, 3)?;
            let mut cells = vec!["dense".to_string()];
            cells.extend(dense_res.iter()
                             .map(|r| format!("{:.2}", r.accuracy())));
            cells.push(format!("{:.2}", mean_accuracy(&dense_res)));
            table.row(&cells);

            for method in methods {
                // prune once; recoveries share the pruned checkpoint, and
                // skip the perplexity stage (zero-shot is the metric here).
                // The checkpoint persists in the run store until every
                // recovery has been measured (crash → no re-prune).
                let pruned = pipe.prune_cached(&store, &fingerprint,
                                               pruner(method)?, pattern)?;
                for rec in recoveries {
                    let rec_label = recovery(rec)?.label();
                    let recovered =
                        pipe.recover_model(&pruned, recovery(rec)?)?;
                    let res = run_suite(&env.session, &recovered.params,
                                        &recovered.masks, &env.corpus,
                                        ITEMS, 3)?;
                    let row_label = if rec == "none" {
                        method.to_string()
                    } else {
                        format!("  {rec_label}")
                    };
                    let mut cells = vec![row_label];
                    cells.extend(res.iter()
                                     .map(|r| format!("{:.2}", r.accuracy())));
                    let mean = mean_accuracy(&res);
                    cells.push(format!("{mean:.2}"));
                    table.row(&cells);
                    results.set(
                        &format!("{}/{}/{}/{}", env.label, pattern.label(),
                                 method, rec_label),
                        Json::Num(mean));
                }
                // every recovery of the group measured: checkpoint is
                // dead weight now
                store.remove_checkpoint(&fingerprint, method, pattern)?;
            }
            table.print();
        }
        env.write_json("table3", &results)?;
    }
    Ok(())
}
