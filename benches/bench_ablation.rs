//! Ablations beyond the paper's tables:
//!   (a) L1 implementation: Pallas masked-matmul ft-step vs plain-XLA
//!       ft-step — numerics must agree; wall-clock compared (on CPU the
//!       interpret-lowered Pallas path is expected slower; on TPU the
//!       Pallas path is the optimized one — see DESIGN.md).
//!   (b) Early-stop: convergence detector on/off — time saved vs ppl cost.
//!   (c) Calibration-split mismatch: fine-tune on eval-distribution data
//!       (oracle) vs the shifted C4-sim split the paper prescribes.

use ebft::bench_support::BenchEnv;
use ebft::config::FtConfig;
use ebft::data::Split;
use ebft::masks::MaskSet;
use ebft::pruning::Pattern;
use ebft::runtime::DeviceBuffer;
use ebft::tensor::Tensor;
use ebft::util::metrics::{fmt_ppl, time_it};
use ebft::util::{Json, Pcg64, TableWriter};

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open(0)?;
    let mut results = Json::obj();

    // ---------- (a) pallas vs xla ft-step ----------
    let d = env.session.manifest.dims.clone();
    let masks = MaskSet::dense(&env.session.manifest);
    let mut rng = Pcg64::seeded(3);
    let x = Tensor::randn(&[d.batch, d.seq, d.d_model], 1.0, &mut rng);
    let target = Tensor::randn(&[d.batch, d.seq, d.d_model], 1.0, &mut rng);
    let bp: Vec<Tensor> = env.dense.block_params(&env.session.manifest, 0)?;
    let zeros: Vec<Tensor> =
        bp.iter().map(|t| Tensor::zeros(&t.shape)).collect();

    // one bound plan per implementation: state uploaded once, so the
    // timed loop measures the step itself, not re-uploads
    let mut plans = Vec::new();
    for name in ["block_ft_step", "block_ft_step_pallas"] {
        let mut plan = env.session.plan(name)?;
        plan.bind_indexed("bp", bp.iter())?;
        plan.bind_indexed("mask", masks.block(0).iter())?;
        for (j, t) in zeros.iter().enumerate() {
            let z = DeviceBuffer::from_tensor(t)?;
            plan.bind(&format!("m.{j}"), &z)?;
            plan.bind(&format!("v.{j}"), &z)?;
        }
        plan.bind_scalar("t", 1.0)?;
        plan.bind_scalar("lr", 1e-2)?;
        plan.bind_tensor("x", &x)?;
        plan.bind_tensor("target", &target)?;
        plans.push(plan);
    }
    fn run_step(plan: &mut ebft::runtime::Plan<'_>) -> anyhow::Result<f32> {
        let outs = plan.run_to_device()?;
        outs.last().unwrap().fetch_scalar()
    }

    let loss_xla = run_step(&mut plans[0])?;
    let loss_pallas = run_step(&mut plans[1])?;
    let rel = ((loss_xla - loss_pallas) / loss_xla.abs().max(1e-9)).abs();
    println!("(a) ft-step loss  xla {loss_xla:.6}  pallas {loss_pallas:.6}  \
              rel-diff {rel:.2e}");
    assert!(rel < 1e-3, "pallas and xla ft-steps disagree");

    let stat_x = time_it(|| { run_step(&mut plans[0]).unwrap(); }, 2, 8);
    let stat_p = time_it(|| { run_step(&mut plans[1]).unwrap(); }, 2, 8);
    let mut table = TableWriter::new(
        "Ablation (a) — L1 implementation of the ft-step hot path",
        &["impl", "mean ms", "min ms"]);
    table.row(&["xla".into(), format!("{:.2}", stat_x.mean * 1e3),
                format!("{:.2}", stat_x.min * 1e3)]);
    table.row(&["pallas(interpret)".into(),
                format!("{:.2}", stat_p.mean * 1e3),
                format!("{:.2}", stat_p.min * 1e3)]);
    table.print();
    results.set("ft_step_ms_xla", Json::Num(stat_x.mean * 1e3));
    results.set("ft_step_ms_pallas", Json::Num(stat_p.mean * 1e3));

    // ---------- (b) early-stop on/off ----------
    // cells run through the scheduler + run store, so EBFT_RESUME=1
    // skips whichever variants a killed run already measured
    let mut table = TableWriter::new(
        "Ablation (b) — convergence early-stop",
        &["early-stop", "ft secs", "ppl"]);
    for (tol, label) in [(1e-3f32, "on"), (0.0, "off")] {
        let ft = FtConfig { converge_tol: tol, ..FtConfig::default() };
        let cell = env.run_cell(ft, "wanda", Pattern::Unstructured(0.7),
                                "ebft")?;
        table.row(&[label.into(), format!("{:.1}", cell.ft_secs),
                    fmt_ppl(cell.ppl)]);
        results.set(&format!("earlystop_{label}_ppl"), Json::Num(cell.ppl));
        results.set(&format!("earlystop_{label}_secs"),
                    Json::Num(cell.ft_secs));
    }
    table.print();

    // ---------- (c) calibration distribution ----------
    // The paper calibrates on C4 but evaluates Wikitext2; our Calib split
    // is likewise shifted from WikiSim. Compare against an oracle that
    // calibrates on the eval distribution itself.
    let mut table = TableWriter::new(
        "Ablation (c) — calibration split (Wanda 70% + EBFT)",
        &["calibration", "ppl"]);
    let ft = FtConfig::default();
    for (split, label) in [(Split::Calib, "C4-sim (paper)"),
                           (Split::WikiSim, "eval-dist (oracle)")] {
        let d = &env.session.manifest.dims;
        let calib = ebft::data::Batcher::with_offset(
            &env.corpus, split, 10_000, ft.calib_seqs, d.batch, d.seq)
            .ordered_batches();
        let mut params = env.dense_params()?.clone();
        let masks = ebft::pruning::prune_model(
            &env.session, &mut params, &ebft::pruning::wanda::Wanda,
            Pattern::Unstructured(0.7), &calib)?;
        let mut ft_params = params.clone();
        ebft::ebft::finetune(&env.session, &env.dense, &mut ft_params, &masks,
                             &ft, &calib, "xla")?;
        let ppl = ebft::eval::perplexity(&env.session, &ft_params, &masks,
                                         &env.corpus, Split::WikiSim, 64)?;
        table.row(&[label.into(), fmt_ppl(ppl)]);
        results.set(&format!("calib_{label}"), Json::Num(ppl));
    }
    table.print();

    env.write_json("ablation", &results)?;
    Ok(())
}
