//! Table 1: Wikitext2-style perplexity at unstructured sparsity 50–90 %,
//! methods {magnitude, wanda, sparsegpt} × {raw, DSnoT, EBFT}.
//!
//! Default grid: MiniLlama-A, sparsities {50, 70, 90}. EBFT_FULL=1 adds
//! MiniLlama-B and sparsities {60, 80} (the paper-complete grid).

use ebft::bench_support::{full_grid, model_indices, BenchEnv};
use ebft::coordinator::FtVariant;
use ebft::pruning::{Method, Pattern};
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};

fn main() -> anyhow::Result<()> {
    let sparsities: Vec<f32> = if full_grid() {
        vec![0.5, 0.6, 0.7, 0.8, 0.9]
    } else {
        vec![0.5, 0.7, 0.9]
    };
    let methods = [Method::Magnitude, Method::Wanda, Method::SparseGpt];
    let variants = [FtVariant::None, FtVariant::Dsnot, FtVariant::Ebft];

    let mut results = Json::obj();
    for model_idx in model_indices() {
        let env = BenchEnv::open(model_idx)?;
        let exp = env.experiment();
        let dense_ppl = exp.dense_ppl()?;
        println!("=== {} (dense ppl {}) ===", env.label, fmt_ppl(dense_ppl));

        let mut headers = vec!["method".to_string()];
        headers.extend(sparsities.iter().map(|s| format!("{}%",
                                                         (s * 100.0) as u32)));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = TableWriter::new(
            &format!("Table 1 — {} unstructured", env.label), &hdr_refs);

        let mut model_json = Json::obj();
        for method in methods {
            for variant in variants {
                let row_label = match variant {
                    FtVariant::None => method.label().to_string(),
                    v => format!("  {}", v.label()),
                };
                let mut cells = vec![row_label.clone()];
                for &s in &sparsities {
                    let cell = exp.run_cell(method, Pattern::Unstructured(s),
                                            variant)?;
                    cells.push(fmt_ppl(cell.ppl));
                    model_json.set(
                        &format!("{}/{}/{}", method.label(),
                                 variant.label(), (s * 100.0) as u32),
                        Json::Num(cell.ppl));
                }
                table.row(&cells);
            }
        }
        table.print();
        model_json.set("dense_ppl", Json::Num(dense_ppl));
        results.set(&env.label.clone(), model_json);
        env.write_json("table1", &results)?;
    }
    Ok(())
}
