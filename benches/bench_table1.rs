//! Table 1: Wikitext2-style perplexity at unstructured sparsity 50–90 %,
//! methods {magnitude, wanda, sparsegpt} × {raw, DSnoT, EBFT}.
//!
//! Default grid: MiniLlama-A, sparsities {50, 70, 90}. EBFT_FULL=1 adds
//! MiniLlama-B and sparsities {60, 80} (the paper-complete grid).
//! EBFT_JOBS=N sweeps cells concurrently (records are byte-identical to
//! the serial run, modulo timings); EBFT_RESUME=1 re-launches an
//! interrupted sweep from runs/store/ without re-running finished cells.

use ebft::bench_support::{full_grid, model_indices, BenchEnv};
use ebft::coordinator::{recovery, Grid};
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};

fn main() -> anyhow::Result<()> {
    let sparsities: Vec<f32> = if full_grid() {
        vec![0.5, 0.6, 0.7, 0.8, 0.9]
    } else {
        vec![0.5, 0.7, 0.9]
    };
    let methods = ["magnitude", "wanda", "sparsegpt"];
    let recoveries = ["none", "dsnot", "ebft"];
    let patterns: Vec<Pattern> =
        sparsities.iter().map(|&s| Pattern::Unstructured(s)).collect();

    let mut results = Json::obj();
    for model_idx in model_indices() {
        let env = BenchEnv::open(model_idx)?;
        let pipe = env.pipeline()?;
        let dense_ppl = pipe.dense_ppl()?;
        println!("=== {} (dense ppl {}) ===", env.label, fmt_ppl(dense_ppl));

        // one scheduled sweep; each pruned checkpoint is shared across
        // recoveries (and across workers under EBFT_JOBS>1)
        let grid = Grid::new(&methods, &patterns, &recoveries)?;
        let swept = env.run_grid(&grid)?;

        let mut headers = vec!["method".to_string()];
        headers.extend(sparsities.iter().map(|s| format!("{}%",
                                                         (s * 100.0) as u32)));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = TableWriter::new(
            &format!("Table 1 — {} unstructured", env.label), &hdr_refs);

        let mut model_json = Json::obj();
        for method in methods {
            for rec in recoveries {
                let rec_label = recovery(rec)?.label();
                let row_label = if rec == "none" {
                    method.to_string()
                } else {
                    format!("  {rec_label}")
                };
                let mut cells = vec![row_label];
                for &s in &sparsities {
                    let cell = swept
                        .find(method, Pattern::Unstructured(s), rec)
                        .expect("grid cell missing");
                    cells.push(fmt_ppl(cell.ppl));
                    model_json.set(
                        &format!("{method}/{rec_label}/{}",
                                 (s * 100.0) as u32),
                        Json::Num(cell.ppl));
                }
                table.row(&cells);
            }
        }
        table.print();
        model_json.set("dense_ppl", Json::Num(dense_ppl));
        results.set(&env.label.clone(), model_json);
        env.write_json("table1", &results)?;
    }
    Ok(())
}
