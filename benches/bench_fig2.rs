//! Figure 2: perplexity of the EBFT-fine-tuned model (Wanda init, 50 %
//! sparsity) as a function of the number of calibration samples.
//!
//! Expected shape: monotone improvement that saturates — and even the
//! smallest calibration set beats no fine-tuning at all.
//!
//! Every cell runs through the scheduler + run store (EBFT_RESUME=1
//! skips cells a killed run already completed). In EBFT_SMOKE=1 mode the
//! single cell additionally writes the CI bench-regression payload
//! (BENCH_pr.json at the repo root, or $EBFT_BENCH_OUT) that
//! python/ci/compare_bench.py gates against BENCH_baseline.json.

use ebft::bench_support::{full_grid, repo_root, BenchEnv};
use ebft::config::FtConfig;
use ebft::coordinator::RunRecord;
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    // EBFT_SMOKE=1: a single cell — CI's hot-loop regression canary for
    // the runtime Plan/DeviceBuffer API (see .github/workflows/ci.yml).
    // With EBFT_BACKEND=reference the smoke cell runs artifact-free on
    // a synthetic tiny manifest (no Python/JAX needed) — the
    // bench-regression job's zero-setup cell, also used to surface the
    // host-kernel speedup (EBFT_THREADS=1 vs N) per PR.
    let smoke = std::env::var("EBFT_SMOKE").map(|v| v == "1")
        .unwrap_or(false);
    let backend = ebft::runtime::BackendKind::from_env();
    let env = if smoke && backend == ebft::runtime::BackendKind::Reference {
        BenchEnv::open_synthetic()?
    } else {
        BenchEnv::open(0)?
    };
    let sample_counts: Vec<usize> = if smoke {
        vec![8]
    } else if full_grid() {
        vec![8, 16, 32, 64, 128, 256]
    } else {
        vec![8, 16, 32, 64, 128]
    };

    // reference: pruned, no fine-tuning
    let base = env.run_cell(FtConfig::default(), "wanda",
                            Pattern::Unstructured(0.5), "none")?;
    println!("wanda@50% before fine-tuning: ppl {}", fmt_ppl(base.ppl));

    let mut table = TableWriter::new(
        "Figure 2 — ppl vs #calibration samples (Wanda 50%, EBFT)",
        &["samples", "perplexity"]);
    let mut series = Json::obj();
    series.set("no_ft", Json::Num(base.ppl));
    for &n in &sample_counts {
        let ft = FtConfig { calib_seqs: n, ..FtConfig::default() };
        let cell = env.run_cell(ft, "wanda", Pattern::Unstructured(0.5),
                                "ebft")?;
        table.row(&[n.to_string(), fmt_ppl(cell.ppl)]);
        series.set(&n.to_string(), Json::Num(cell.ppl));
        if smoke {
            write_bench_payload(&cell, n,
                                env.session.backend_kind().as_str())?;
        }
    }
    table.print();
    env.write_json("fig2", &series)?;
    Ok(())
}

/// The CI bench-regression payload: the smoke cell's quality (ppl) and
/// cost (per-stage wall-clock, incl. the residency model's one-off
/// per-block bind time) in the shape python/ci/compare_bench.py reads.
fn write_bench_payload(cell: &RunRecord, calib: usize, backend: &str)
                       -> anyhow::Result<()> {
    let bind_secs: f64 = cell
        .ebft_report
        .as_ref()
        .map(|r| r.per_block.iter().map(|b| b.bind_secs).sum())
        .unwrap_or(0.0);
    let mut j = Json::obj();
    j.set("cell", Json::Str(cell.key()));
    j.set("backend", Json::Str(backend.to_string()));
    j.set("threads",
          Json::Num(ebft::tensor::kernels::threads() as f64));
    j.set("calib_seqs", Json::Num(calib as f64));
    j.set("ppl", Json::Num(cell.ppl));
    j.set("prune_secs", Json::Num(cell.prune_secs));
    j.set("ft_secs", Json::Num(cell.ft_secs));
    j.set("eval_secs", Json::Num(cell.eval_secs));
    j.set("bind_secs", Json::Num(bind_secs));
    j.set("wall_secs",
          Json::Num(cell.prune_secs + cell.ft_secs + cell.eval_secs));
    let path = match std::env::var("EBFT_BENCH_OUT") {
        Ok(p) => PathBuf::from(p),
        Err(_) => repo_root().join("BENCH_pr.json"),
    };
    j.write_file(&path)?;
    println!("[bench-regression payload written to {}]", path.display());
    Ok(())
}
