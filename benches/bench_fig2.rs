//! Figure 2: perplexity of the EBFT-fine-tuned model (Wanda init, 50 %
//! sparsity) as a function of the number of calibration samples.
//!
//! Expected shape: monotone improvement that saturates — and even the
//! smallest calibration set beats no fine-tuning at all.

use ebft::bench_support::{full_grid, BenchEnv};
use ebft::config::FtConfig;
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open(0)?;
    // EBFT_SMOKE=1: a single cell — CI's hot-loop regression canary for
    // the runtime Plan/DeviceBuffer API (see .github/workflows/ci.yml)
    let smoke = std::env::var("EBFT_SMOKE").map(|v| v == "1")
        .unwrap_or(false);
    let sample_counts: Vec<usize> = if smoke {
        vec![8]
    } else if full_grid() {
        vec![8, 16, 32, 64, 128, 256]
    } else {
        vec![8, 16, 32, 64, 128]
    };

    // reference: pruned, no fine-tuning
    let base = env
        .pipeline()?
        .run_named("wanda", Pattern::Unstructured(0.5), "none")?;
    println!("wanda@50% before fine-tuning: ppl {}", fmt_ppl(base.ppl));

    let mut table = TableWriter::new(
        "Figure 2 — ppl vs #calibration samples (Wanda 50%, EBFT)",
        &["samples", "perplexity"]);
    let mut series = Json::obj();
    series.set("no_ft", Json::Num(base.ppl));
    for &n in &sample_counts {
        let pipe = env.pipeline_with(FtConfig { calib_seqs: n,
                                                ..FtConfig::default() })?;
        let cell = pipe.run_named("wanda", Pattern::Unstructured(0.5),
                                  "ebft")?;
        table.row(&[n.to_string(), fmt_ppl(cell.ppl)]);
        series.set(&n.to_string(), Json::Num(cell.ppl));
    }
    table.print();
    env.write_json("fig2", &series)?;
    Ok(())
}
