//! Table 6: weight tuning (EBFT) vs mask tuning on the same block-wise
//! objective, Wanda initialization, sparsity 50–90 %.
//!
//! Expected shape (paper §4.5): mask tuning beats DSnoT but loses to
//! weight tuning at every sparsity.
//! EBFT_JOBS=N for concurrent cells, EBFT_RESUME=1 to resume (see
//! bench_support).

use ebft::bench_support::{full_grid, model_indices, BenchEnv};
use ebft::coordinator::Grid;
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};

fn main() -> anyhow::Result<()> {
    let sparsities: Vec<f32> = if full_grid() {
        vec![0.5, 0.6, 0.7, 0.8, 0.9]
    } else {
        vec![0.5, 0.7, 0.9]
    };
    let patterns: Vec<Pattern> =
        sparsities.iter().map(|&s| Pattern::Unstructured(s)).collect();
    let mut results = Json::obj();
    for model_idx in model_indices() {
        let env = BenchEnv::open(model_idx)?;
        println!("=== {} ===", env.label);

        let grid = Grid::new(&["wanda"], &patterns, &["masktune", "ebft"])?;
        let swept = env.run_grid(&grid)?;

        let mut headers = vec!["method".to_string()];
        headers.extend(sparsities.iter()
                           .map(|s| format!("{}%", (s * 100.0) as u32)));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut table = TableWriter::new(
            &format!("Table 6 — {} mask vs weight tuning (Wanda init)",
                     env.label),
            &hdr_refs);
        for (rec, label) in [("masktune", "w.Mask"), ("ebft", "w.Weight")] {
            let mut cells = vec![label.to_string()];
            for &s in &sparsities {
                let cell = swept
                    .find("wanda", Pattern::Unstructured(s), rec)
                    .expect("grid cell missing");
                cells.push(fmt_ppl(cell.ppl));
                results.set(&format!("{}/{}/{}", env.label, label,
                                     (s * 100.0) as u32),
                            Json::Num(cell.ppl));
            }
            table.row(&cells);
        }
        table.print();
        env.write_json("table6", &results)?;
    }
    Ok(())
}
