//! Memory-footprint demonstration — the paper's "single 16 GB GPU" claim.
//!
//! EBFT's resident set while fine-tuning block `l` is: one block's weights +
//! optimizer state, plus two activation streams (student inputs, teacher
//! targets). This example runs the same EBFT pipeline under an aggressively
//! small activation-cache budget and shows (a) the spill machinery keeps the
//! resident bytes bounded, (b) results are bit-identical to the unbounded
//! run — i.e. the memory ceiling is a pure streaming trade, exactly the
//! property that lets the paper fine-tune Llama-7B on 16 GB.
//!
//!   cargo run --release --example memory_footprint

use ebft::bench_support::BenchEnv;
use ebft::config::FtConfig;
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;

fn main() -> anyhow::Result<()> {
    let env = BenchEnv::open(0)?;
    let d = env.session.manifest.dims.clone();
    let batch_bytes = d.batch * d.seq * d.d_model * 4;
    println!("activation batch = {} KiB; calib stream = {} batches",
             batch_bytes / 1024, 64 / d.batch);

    let mut results = Vec::new();
    for (label, budget) in [
        ("unbounded (all resident)", usize::MAX / 4),
        ("4 batches resident", 4 * 2 * batch_bytes),
        ("1 batch resident (max spill)", 2 * batch_bytes),
    ] {
        let pipe = env.pipeline_with(FtConfig { cache_budget_bytes: budget,
                                                ..FtConfig::default() })?;
        let t0 = std::time::Instant::now();
        let cell = pipe.run_named("wanda", Pattern::Unstructured(0.7),
                                  "ebft")?;
        println!("{label:<30} ppl {}  ({:.1}s)", fmt_ppl(cell.ppl),
                 t0.elapsed().as_secs_f64());
        results.push(cell.ppl);
    }
    let max_dev = results
        .iter()
        .map(|p| (p - results[0]).abs())
        .fold(0.0f64, f64::max);
    assert!(max_dev < 1e-6,
            "spilling changed results: {results:?}");
    println!("\nall budgets bit-identical — streaming is a pure memory/IO \
              trade (the 16 GB-GPU story). memory_footprint OK");
    Ok(())
}
