//! LoRA vs EBFT (§4.4 in miniature): structured FLAP pruning at 20 %,
//! then recover with either LoRA (full-model adapters, big instruct-sim
//! split) or EBFT (block-wise, 64 calibration sequences). Reports wall
//! clock and perplexity — the paper's Table 4 claim is ~10× cheaper
//! fine-tuning at equal-or-better quality.
//!
//!   cargo run --release --example lora_vs_ebft -- [--lora-steps 800]

use ebft::bench_support::BenchEnv;
use ebft::config::FtConfig;
use ebft::coordinator::{pruner, recovery};
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Args, TableWriter};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let lora_steps = args.get_usize("lora-steps", 800)?;
    let env = BenchEnv::open(0)?;
    let pipe = env.pipeline_with(FtConfig { lora_steps,
                                            ..FtConfig::default() })?;
    println!("dense ppl {}", fmt_ppl(pipe.dense_ppl()?));

    let mut table = TableWriter::new("LoRA vs EBFT at 20% structured",
                                     &["method", "time(s)", "ppl"]);
    // FLAP once; both recoveries share the pruned checkpoint
    let ckpt = pipe.prune(pruner("flap")?, Pattern::Structured(0.20))?;
    let (_, _, lora) = pipe.recover(&ckpt, recovery("lora")?)?;
    table.row(&["LoRA".into(), format!("{:.1}", lora.ft_secs),
                fmt_ppl(lora.ppl)]);

    let (_, _, ours) = pipe.recover(&ckpt, recovery("ebft")?)?;
    table.row(&["EBFT".into(), format!("{:.1}", ours.ft_secs),
                fmt_ppl(ours.ppl)]);
    table.print();
    println!("speedup: {:.1}×", lora.ft_secs / ours.ft_secs.max(1e-9));
    Ok(())
}
