//! LoRA vs EBFT (§4.4 in miniature): structured FLAP pruning at 20 %,
//! then recover with either LoRA (full-model adapters, big instruct-sim
//! split) or EBFT (block-wise, 64 calibration sequences). Reports wall
//! clock and perplexity — the paper's Table 4 claim is ~10× cheaper
//! fine-tuning at equal-or-better quality.
//!
//!   cargo run --release --example lora_vs_ebft -- [--lora-steps 800]

use ebft::bench_support::BenchEnv;
use ebft::data::Split;
use ebft::eval;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Args, TableWriter};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let lora_steps = args.get_usize("lora-steps", 800)?;
    let env = BenchEnv::open(0)?;
    let exp = env.experiment();
    println!("dense ppl {}", fmt_ppl(exp.dense_ppl()?));

    let mut table = TableWriter::new("LoRA vs EBFT at 20% structured",
                                     &["method", "time(s)", "ppl"]);
    let (lp, lm, lsecs) = exp.run_structured(0.20, true, lora_steps)?;
    let lppl = eval::perplexity(&env.session, &lp, &lm, &env.corpus,
                                Split::WikiSim, 64)?;
    table.row(&["LoRA".into(), format!("{lsecs:.1}"), fmt_ppl(lppl)]);

    let (ep, em, esecs) = exp.run_structured(0.20, false, 0)?;
    let eppl = eval::perplexity(&env.session, &ep, &em, &env.corpus,
                                Split::WikiSim, 64)?;
    table.row(&["EBFT".into(), format!("{esecs:.1}"), fmt_ppl(eppl)]);
    table.print();
    println!("speedup: {:.1}×", lsecs / esecs.max(1e-9));
    Ok(())
}
