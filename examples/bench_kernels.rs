//! Per-kernel microbench + determinism rig — the CI bench-regression
//! job's kernel gate (README §CI).
//!
//!   cargo run --release --example bench_kernels
//!
//! Times every host kernel at one representative shape, for each
//! storage dtype (f32 inputs, and the same inputs rounded to bf16) and
//! each numeric tier: the exact tier on both the scalar path and the
//! detected SIMD path, the fast tier on the detected path (the
//! exact-vs-fast speedup cell compare_bench.py gates ≥1.3× for
//! `silu_mul`/`recon_loss_grad` on SIMD hosts). Median of
//! `EBFT_BENCH_REPS` (default 5) timed runs after one warmup. The
//! payload lands in BENCH_kernels.json at the repo root (override:
//! `EBFT_BENCH_OUT`); python/ci/compare_bench.py --kernels gates it
//! per kernel against the committed BENCH_kernels_baseline.json.
//!
//! Before any timing, the rig checks the numeric contract on every
//! (kernel × dtype) cell, tier-aware: at the **exact** tier outputs
//! must be bit-identical across thread counts (1 vs 4) and across the
//! scalar ↔ detected SIMD paths; at the **fast** tier the same
//! bit-identity must hold (the fast tier is its own deterministic
//! universe — every fused op is the correctly rounded IEEE fma) *and*
//! outputs must sit within the documented per-kernel tolerance of the
//! exact tier ([`fast_tol`], DESIGN.md §Kernels). The rig exits
//! nonzero on the first violation, so CI fails even when the baseline
//! is still null-seeded. On a host without SIMD both paths run scalar;
//! the JSON records `simd_path: "scalar"` and the compare script skips
//! the speedup gates. In the bf16 sweep the fast tier runs under
//! `Dtype::Bf16`, so the matmul family exercises the native
//! bf16-operand cores (the inputs are bf16-exact, making the pack
//! lossless — any mismatch vs the f32 fast path is a real bug).
//!
//! Everything here is std-only (no artifacts, no Python): inputs are
//! seeded `Pcg64` tensors, the sparse cells build their formats through
//! the real `EffWeight` dispatcher.

use anyhow::{bail, Result};
use ebft::bench_support::repo_root;
use ebft::tensor::dtype::{quantize_bf16, set_dtype};
use ebft::tensor::kernels::{self, AdamHyper, MathTier, SimdPath};
use ebft::tensor::sparse::{EffWeight, SparseMode};
use ebft::tensor::{Dtype, Tensor};
use ebft::util::{Json, Pcg64};
use std::path::PathBuf;
use std::time::Instant;

/// Matmul-family shape (M×K @ K×N): the ISSUE's reference point for
/// the SIMD speedup gate.
const M: usize = 256;
const K: usize = 512;
const N: usize = 1024;

struct Inputs {
    a: Tensor,      // [M, K]
    at: Tensor,     // [K, M]
    b: Tensor,      // [K, N]
    bt: Tensor,     // [N, K]
    gate: Tensor,   // [M, N]
    up: Tensor,     // [M, N]
    dh: Tensor,     // [M, N]
    target: Tensor, // [M, N]
    p: Tensor,      // [K, N]
    g: Tensor,      // [K, N]
    m: Tensor,      // [K, N]
    v: Tensor,      // [K, N] (non-negative: Adam second moment)
    mask: Tensor,   // [K, N] ~50% kept, unstructured
    nm: EffWeight,  // 2:4 structured W⊙M of [K, N] (panel_axpy core)
    csr: EffWeight, // unstructured ~50% W⊙M of [K, N] (gather_axpy core)
}

impl Inputs {
    fn build(bf16: bool) -> Result<Inputs> {
        let mut rng = Pcg64::seeded(17);
        let mut t = |shape: &[usize]| {
            let mut x = Tensor::randn(shape, 1.0, &mut rng);
            if bf16 {
                for v in x.data.iter_mut() {
                    *v = quantize_bf16(*v);
                }
            }
            x
        };
        let a = t(&[M, K]);
        let at = kernels::transpose(&a)?;
        let b = t(&[K, N]);
        let bt = t(&[N, K]);
        let gate = t(&[M, N]);
        let up = t(&[M, N]);
        let dh = t(&[M, N]);
        let target = t(&[M, N]);
        let p = t(&[K, N]);
        let g = t(&[K, N]);
        let m = t(&[K, N]);
        let mut v = t(&[K, N]);
        for x in v.data.iter_mut() {
            *x = x.abs();
        }
        // unstructured ~50% mask (0/1 is bf16-exact, no quantization
        // needed); also the mask_mul timing input
        let mut mask = Tensor::zeros(&[K, N]);
        for x in mask.data.iter_mut() {
            *x = (rng.next_f32() < 0.5) as u32 as f32;
        }
        // 2:4 structured mask along k, kept offsets varying per output
        // column so no full row/column zeroes out (the dispatcher must
        // land on the N:M panel format, not rows/cols)
        let mut nm_mask = Tensor::zeros(&[K, N]);
        for j in 0..N {
            let o = j % 3; // kept in-group offsets {o, o+1} ⊂ {0..3}
            for gi in 0..K / 4 {
                nm_mask.data[(4 * gi + o) * N + j] = 1.0;
                nm_mask.data[(4 * gi + o + 1) * N + j] = 1.0;
            }
        }
        // reuse the Adam param tensor as the sparse weight
        let nm = EffWeight::from_masked_mode(&p, &nm_mask, SparseMode::Force);
        let csr = EffWeight::from_masked_mode(&p, &mask, SparseMode::Force);
        if nm.format() != "nm" || csr.format() != "csr" {
            bail!("sparse dispatcher picked {}/{} (want nm/csr) — the \
                   bench masks no longer exercise panel_axpy/gather_axpy",
                  nm.format(), csr.format());
        }
        Ok(Inputs { a, at, b, bt, gate, up, dh, target,
                    p, g, m, v, mask, nm, csr })
    }
}

type Kernel = (&'static str, String, fn(&Inputs) -> Vec<f32>);

/// Every timed kernel, returning its full output bits (flattened) so
/// the determinism check can compare runs exactly.
fn kernel_table() -> Vec<Kernel> {
    let mmshape = format!("{M}x{K}x{N}");
    let ewshape = format!("{M}x{N}");
    let pshape = format!("{K}x{N}");
    vec![
        ("matmul", mmshape.clone(), |i| {
            kernels::matmul(&i.a, &i.b).unwrap().data
        }),
        ("matmul_at_b", mmshape.clone(), |i| {
            kernels::matmul_at_b(&i.at, &i.b).unwrap().data
        }),
        ("matmul_a_bt", mmshape.clone(), |i| {
            kernels::matmul_a_bt(&i.a, &i.bt).unwrap().data
        }),
        ("gram", format!("{M}x{K}"), |i| {
            kernels::gram(&i.a).unwrap().data
        }),
        ("silu_mul", ewshape.clone(), |i| {
            kernels::silu_mul(&i.gate, &i.up).data
        }),
        ("silu_mul_bwd", ewshape.clone(), |i| {
            let (dg, du) = kernels::silu_mul_bwd(&i.dh, &i.gate, &i.up);
            let mut out = dg.data;
            out.extend(du.data);
            out
        }),
        ("adam_step", pshape.clone(), |i| {
            let h = AdamHyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 };
            let (p, m, v) =
                kernels::adam_step(&i.p, &i.g, &i.m, &i.v, 3.0, 1e-3, h);
            let mut out = p.data;
            out.extend(m.data);
            out.extend(v.data);
            out
        }),
        ("recon_loss_grad", ewshape.clone(), |i| {
            let (loss, dy) = kernels::recon_loss_grad(&i.gate, &i.target);
            let mut out = vec![loss];
            out.extend(dy.data);
            out
        }),
        ("add_assign", pshape.clone(), |i| {
            let mut acc = i.p.clone();
            kernels::add_assign(&mut acc, &i.g);
            acc.data
        }),
        ("mask_mul", pshape.clone(), |i| {
            kernels::mask_mul(&i.p, &i.mask).data
        }),
        ("col_stats", ewshape, |i| {
            let (sq, su) = kernels::col_stats(&i.gate);
            let mut out = sq;
            out.extend(su);
            out
        }),
        ("panel_axpy", pshape.clone(), |i| {
            i.nm.matmul(&i.a).unwrap().data
        }),
        ("gather_axpy", pshape, |i| {
            i.csr.matmul_bt(&i.gate).unwrap().data
        }),
    ]
}

fn assert_bits_eq(a: &[f32], b: &[f32], tag: &str) -> Result<()> {
    if a.len() != b.len() {
        bail!("{tag}: output lengths differ ({} vs {})", a.len(), b.len());
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.to_bits() != y.to_bits() {
            bail!("{tag}: element {i} differs: {x} vs {y} — the \
                   determinism contract is broken");
        }
    }
    Ok(())
}

/// Fast-tier acceptance bounds vs the exact tier as `(rel, abs)`, per
/// the numeric-contract table in DESIGN.md §Kernels. Matmul-family
/// bounds absorb fma re-rounding over K=512-term dots at the bench's
/// unit-normal input scale; the silu pair is bounded by the ≤8-ulp
/// polynomial exp; the recon loss trades the f64 scalar accumulator
/// for f32 lane trees. Kernels with no fast core return `(0, 0)`:
/// they must stay bit-identical across tiers.
fn fast_tol(name: &str) -> (f64, f64) {
    match name {
        "matmul" | "matmul_at_b" | "matmul_a_bt" | "gram"
        | "panel_axpy" | "gather_axpy" => (1e-4, 1e-3),
        "silu_mul" | "silu_mul_bwd" => (1e-5, 1e-5),
        "recon_loss_grad" => (1e-3, 1e-5),
        _ => (0.0, 0.0),
    }
}

/// `|a−b| ≤ abs + rel·max(|a|,|b|)` elementwise; `(0, 0)` degrades to
/// the bitwise check (tier-invariant kernels).
fn assert_close(a: &[f32], b: &[f32], rel: f64, abs: f64, tag: &str)
                -> Result<()> {
    if rel == 0.0 && abs == 0.0 {
        return assert_bits_eq(a, b, tag);
    }
    if a.len() != b.len() {
        bail!("{tag}: output lengths differ ({} vs {})", a.len(), b.len());
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let (xf, yf) = (x as f64, y as f64);
        let lim = abs + rel * xf.abs().max(yf.abs());
        if !((xf - yf).abs() <= lim) {
            bail!("{tag}: element {i} outside the fast-tier tolerance: \
                   {x} vs {y} (|Δ| {:.3e} > {lim:.3e})", (xf - yf).abs());
        }
    }
    Ok(())
}

/// Median of `reps` timed runs after one warmup (which also yields the
/// reference output for the determinism checks).
fn time_kernel(f: fn(&Inputs) -> Vec<f32>, inputs: &Inputs, reps: usize)
               -> (f64, Vec<f32>) {
    let reference = f(inputs);
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f(inputs));
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    (samples[reps / 2], reference)
}

fn main() -> Result<()> {
    let reps = std::env::var("EBFT_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(5);
    let detected = SimdPath::detected();
    let timing_threads = kernels::threads();
    // the rig drives both tiers itself; whatever EBFT_MATH asked for is
    // restored on exit
    let prev_tier = kernels::set_math_tier(MathTier::Exact);
    println!("bench-kernels: simd path {} | {} timing threads | \
              median of {reps}", detected.as_str(), timing_threads);

    let mut entries: Vec<Json> = Vec::new();
    for (dtype, bf16) in [("f32", false), ("bf16", true)] {
        let inputs = Inputs::build(bf16)?;
        for (name, shape, f) in kernel_table() {
            // exact-tier determinism first: scalar output is the golden
            // reference; 1 vs 4 threads and scalar vs detected must
            // agree bitwise
            let prev_path = kernels::set_simd_path(SimdPath::Scalar);
            let prev_threads = kernels::set_threads(1);
            let golden = f(&inputs);
            kernels::set_threads(4);
            assert_bits_eq(&f(&inputs), &golden,
                           &format!("{name}/{dtype} threads 1 vs 4"))?;
            kernels::set_simd_path(detected);
            assert_bits_eq(&f(&inputs), &golden,
                           &format!("{name}/{dtype} scalar vs {}",
                                    detected.as_str()))?;
            kernels::set_threads(prev_threads);

            // exact timing: both paths at the process thread target
            kernels::set_simd_path(SimdPath::Scalar);
            let (scalar_secs, _) = time_kernel(f, &inputs, reps);
            kernels::set_simd_path(detected);
            let (simd_secs, _) = time_kernel(f, &inputs, reps);

            // fast tier: the bf16 sweep flips the active dtype so the
            // matmul family runs its native bf16-operand cores
            kernels::set_math_tier(MathTier::Fast);
            let prev_dtype = bf16.then(|| set_dtype(Dtype::Bf16));
            kernels::set_simd_path(SimdPath::Scalar);
            kernels::set_threads(1);
            let fast_golden = f(&inputs);
            let (rel, abs) = fast_tol(name);
            // within documented tolerance of the exact tier…
            assert_close(&fast_golden, &golden, rel, abs,
                         &format!("{name}/{dtype} fast vs exact"))?;
            // …and bit-deterministic in its own right
            kernels::set_threads(4);
            assert_bits_eq(&f(&inputs), &fast_golden,
                           &format!("{name}/{dtype} fast threads 1 vs 4"))?;
            kernels::set_simd_path(detected);
            assert_bits_eq(&f(&inputs), &fast_golden,
                           &format!("{name}/{dtype} fast scalar vs {}",
                                    detected.as_str()))?;
            kernels::set_threads(prev_threads);
            let (fast_secs, _) = time_kernel(f, &inputs, reps);
            if let Some(d) = prev_dtype {
                set_dtype(d);
            }
            kernels::set_math_tier(MathTier::Exact);
            kernels::set_simd_path(prev_path);

            for (math, path, secs) in
                [("exact", "scalar", scalar_secs),
                 ("exact", detected.as_str(), simd_secs),
                 ("fast", detected.as_str(), fast_secs)] {
                let mut e = Json::obj();
                e.set("kernel", Json::Str(name.to_string()));
                e.set("shape", Json::Str(shape.clone()));
                e.set("dtype", Json::Str(dtype.to_string()));
                e.set("path", Json::Str(path.to_string()));
                e.set("math", Json::Str(math.to_string()));
                e.set("secs", Json::Num(secs));
                entries.push(e);
            }
            println!("bench-kernels: {name:<16} {dtype:<4} {shape:<12} \
                      scalar {scalar_secs:.6}s  {} {simd_secs:.6}s  \
                      speedup {:.2}x  fast {fast_secs:.6}s  \
                      exact-vs-fast {:.2}x", detected.as_str(),
                     scalar_secs / simd_secs.max(1e-12),
                     simd_secs / fast_secs.max(1e-12));
        }
    }
    kernels::set_math_tier(prev_tier);
    println!("bench-kernels: numeric contract OK — exact bit-identical \
              across 1/4 threads and scalar/{}, fast bit-deterministic \
              and within tolerance of exact, at both dtypes",
             detected.as_str());

    let mut j = Json::obj();
    j.set("simd_path", Json::Str(detected.as_str().to_string()));
    j.set("threads", Json::Num(timing_threads as f64));
    j.set("reps", Json::Num(reps as f64));
    j.set("kernels", Json::Arr(entries));
    let path = match std::env::var("EBFT_BENCH_OUT") {
        Ok(p) => PathBuf::from(p),
        Err(_) => repo_root().join("BENCH_kernels.json"),
    };
    j.write_file(&path)?;
    println!("[kernel bench payload written to {}]", path.display());
    Ok(())
}
