//! Sparsity sweep (Table 1 in miniature): how EBFT's advantage over the
//! raw pruner and DSnoT widens as sparsity grows. Driven by one scheduled
//! `Grid` sweep: each sparsity is pruned once and shared across the three
//! recovery variants, and independent cells run concurrently under
//! `--jobs N` (each worker with its own session).
//!
//!   cargo run --release --example sparsity_sweep -- \
//!       [--method wanda] [--jobs 4] [--resume]
//!
//! `--jobs`/`--resume` default to the EBFT_JOBS / EBFT_RESUME=1 env vars.

use ebft::bench_support::{self, BenchEnv};
use ebft::config::FtConfig;
use ebft::coordinator::{pruner, Grid};
use ebft::pruning::Pattern;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Args, TableWriter};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let method = pruner(args.get_or("method", "wanda"))?;
    let jobs = args.get_usize("jobs", bench_support::jobs())?;
    let resume = args.has_flag("resume") || bench_support::resume();
    let env = BenchEnv::open(0)?;
    let pipe = env.pipeline()?;
    let dense_ppl = pipe.dense_ppl()?;
    println!("{} dense ppl {}", env.label, fmt_ppl(dense_ppl));

    let patterns: Vec<Pattern> = [0.5f32, 0.6, 0.7, 0.8]
        .iter()
        .map(|&s| Pattern::Unstructured(s))
        .collect();
    let grid = Grid::new(&[method.name()], &patterns,
                         &["none", "dsnot", "ebft"])?;
    let swept = env.sweep(&grid, FtConfig::default(), jobs, resume)?;

    let mut table = TableWriter::new(
        &format!("sparsity sweep — {} + fine-tuning variants",
                 method.label()),
        &["sparsity", "pruned", "w.DSnoT", "w.Ours(EBFT)"]);
    for &p in &patterns {
        let cell = |rec: &str| {
            swept.find(method.name(), p, rec).expect("grid cell missing")
        };
        table.row(&[p.label(), fmt_ppl(cell("none").ppl),
                    fmt_ppl(cell("dsnot").ppl), fmt_ppl(cell("ebft").ppl)]);
    }
    table.print();
    println!("expected shape: EBFT column ≤ both others, gap widening \
              with sparsity");
    Ok(())
}
