//! Sparsity sweep (Table 1 in miniature): how EBFT's advantage over the
//! raw pruner and DSnoT widens as sparsity grows.
//!
//!   cargo run --release --example sparsity_sweep -- [--method wanda]

use ebft::bench_support::BenchEnv;
use ebft::coordinator::FtVariant;
use ebft::pruning::{Method, Pattern};
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Args, TableWriter};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let method = Method::parse(args.get_or("method", "wanda"))?;
    let env = BenchEnv::open(0)?;
    let exp = env.experiment();
    let dense_ppl = exp.dense_ppl()?;
    println!("{} dense ppl {}", env.label, fmt_ppl(dense_ppl));

    let mut table = TableWriter::new(
        &format!("sparsity sweep — {} + fine-tuning variants",
                 method.label()),
        &["sparsity", "pruned", "w.DSnoT", "w.Ours(EBFT)"]);
    for s in [0.5f32, 0.6, 0.7, 0.8] {
        let p = Pattern::Unstructured(s);
        let raw = exp.run_cell(method, p, FtVariant::None)?;
        let dsnot = exp.run_cell(method, p, FtVariant::Dsnot)?;
        let ours = exp.run_cell(method, p, FtVariant::Ebft)?;
        table.row(&[p.label(), fmt_ppl(raw.ppl), fmt_ppl(dsnot.ppl),
                    fmt_ppl(ours.ppl)]);
    }
    table.print();
    println!("expected shape: EBFT column ≤ both others, gap widening \
              with sparsity");
    Ok(())
}
