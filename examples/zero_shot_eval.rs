//! Zero-shot generality (Table 3 in miniature): how much of the dense
//! model's task accuracy survives 60 % pruning, and how much EBFT restores.
//!
//!   cargo run --release --example zero_shot_eval -- [--items 32]

use ebft::bench_support::BenchEnv;
use ebft::coordinator::{pruner, recovery};
use ebft::eval::zeroshot::{mean_accuracy, run_suite};
use ebft::masks::MaskSet;
use ebft::pruning::Pattern;
use ebft::util::{Args, TableWriter};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    let items = args.get_usize("items", 32)?;
    let env = BenchEnv::open(0)?;
    let pipe = env.pipeline()?;
    let pattern = Pattern::Unstructured(0.6);

    let dense_masks = MaskSet::dense(&env.session.manifest);
    let dense = run_suite(&env.session, env.dense_params()?, &dense_masks,
                          &env.corpus, items, 3)?;
    // prune once; both variants share the pruned checkpoint (and skip the
    // perplexity stage — accuracy is the metric here)
    let ckpt = pipe.prune(pruner("wanda")?, pattern)?;
    let raw = pipe.recover_model(&ckpt, recovery("none")?)?;
    let pruned = run_suite(&env.session, &raw.params, &raw.masks,
                           &env.corpus, items, 3)?;
    let ebft = pipe.recover_model(&ckpt, recovery("ebft")?)?;
    let tuned = run_suite(&env.session, &ebft.params, &ebft.masks,
                          &env.corpus, items, 3)?;

    let mut table = TableWriter::new(
        "zero-shot accuracy @ wanda 60%",
        &["task", "dense", "pruned", "EBFT"]);
    for ((d, p), t) in dense.iter().zip(&pruned).zip(&tuned) {
        table.row(&[d.task.to_string(), format!("{:.1}", d.accuracy()),
                    format!("{:.1}", p.accuracy()),
                    format!("{:.1}", t.accuracy())]);
    }
    table.row(&["MEAN".into(), format!("{:.1}", mean_accuracy(&dense)),
                format!("{:.1}", mean_accuracy(&pruned)),
                format!("{:.1}", mean_accuracy(&tuned))]);
    table.print();
    Ok(())
}
