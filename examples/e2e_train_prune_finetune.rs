//! End-to-end driver (the repo's validation workload, see DESIGN.md):
//! pretrain the `small` MiniLlama for a few hundred steps on the synthetic
//! corpus with the loss curve logged, prune at 50 % and 70 % with Wanda,
//! recover with EBFT, and report the full perplexity table plus per-block
//! timing.
//!
//!   cargo run --release --example e2e_train_prune_finetune
//!
//! The prune/recover stage runs as one scheduled grid: EBFT_JOBS=2 works
//! the two sparsities concurrently, EBFT_RESUME=1 resumes a killed run
//! from runs/store/.

use ebft::bench_support::{BenchEnv, BASE_STEPS};
use ebft::coordinator::Grid;
use ebft::data::{MarkovCorpus, Split};
use ebft::pretrain;
use ebft::pruning::Pattern;
use ebft::runtime::Session;
use ebft::util::metrics::fmt_ppl;
use ebft::util::{Json, TableWriter};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let session = Session::open_dir(&root.join("artifacts/small"))?;
    let corpus = MarkovCorpus::new(session.manifest.dims.vocab, 7);

    // --- stage 1: pretraining with loss curve ---
    // (force a fresh run so the loss curve is shown; benches reuse the
    // cached checkpoint via BenchEnv)
    println!("== stage 1: pretraining MiniLlama-small ({BASE_STEPS} steps) ==");
    let (dense, report) =
        pretrain::pretrain(&session, &corpus, BASE_STEPS, 3e-3, 0, 25)?;
    println!("loss curve (step, loss):");
    for (s, l) in &report.loss_curve {
        let bar = "#".repeat((l * 8.0) as usize);
        println!("  {s:>5}  {l:7.4}  {bar}");
    }
    println!("pretraining took {:.1}s", report.secs);

    // --- stage 2/3: prune + EBFT at two sparsities, one scheduled grid ---
    let env = BenchEnv {
        session,
        corpus,
        dense: ebft::model::DenseModel::resident(dense),
        runs: root.join("runs"),
        label: "MiniLlama-A".into(),
        artifact_dir: root.join("artifacts/small"),
        // pretrain() above is deterministic in (seed, steps); this is the
        // same teacher the cached benches use
        dense_tag: format!("small-seed0-steps{BASE_STEPS}"),
    };
    let pipe = env.pipeline()?;
    let dense_ppl = pipe.dense_ppl()?;

    let grid = Grid::new(
        &["wanda"],
        &[Pattern::Unstructured(0.5), Pattern::Unstructured(0.7)],
        &["none", "ebft"])?;
    let swept = env.run_grid(&grid)?;

    let mut table = TableWriter::new(
        "end-to-end: Wanda pruning + EBFT recovery (wiki-sim ppl)",
        &["sparsity", "dense", "pruned", "EBFT", "ft secs"]);
    let mut results = Json::obj();
    results.set("dense_ppl", Json::Num(dense_ppl));
    for s in [0.5f32, 0.7] {
        let pattern = Pattern::Unstructured(s);
        let pruned = swept.find("wanda", pattern, "none")
            .expect("missing pruned cell");
        let tuned = swept.find("wanda", pattern, "ebft")
            .expect("missing ebft cell");
        table.row(&[format!("{}%", (s * 100.0) as u32), fmt_ppl(dense_ppl),
                    fmt_ppl(pruned.ppl), fmt_ppl(tuned.ppl),
                    format!("{:.1}", tuned.ft_secs)]);
        let key = format!("s{}", (s * 100.0) as u32);
        results.set(&format!("{key}_pruned"), Json::Num(pruned.ppl));
        results.set(&format!("{key}_ebft"), Json::Num(tuned.ppl));
        if let Some(r) = &tuned.ebft_report {
            println!("per-block @ {}%:", (s * 100.0) as u32);
            for b in &r.per_block {
                println!("  block {}: {:>2} epochs, {:.2}s, loss {:.4} → {:.4}{}",
                         b.block, b.epochs_run, b.secs, b.first_loss,
                         b.last_loss,
                         if b.converged_early { " [early]" } else { "" });
            }
        }
    }
    table.print();

    // --- stage 4: held-out splits sanity ---
    let masks = ebft::masks::MaskSet::dense(&env.session.manifest);
    let calib_ppl = ebft::eval::perplexity(&env.session, env.dense_params()?,
                                           &masks, &env.corpus,
                                           Split::Calib, 32)?;
    println!("dense ppl on calib split (distribution-shifted): {}",
             fmt_ppl(calib_ppl));

    env.write_json("e2e", &results)?;
    println!("e2e driver OK");
    Ok(())
}
