//! Quickstart: the whole EBFT story on the `tiny` config in under a minute.
//!
//!   cargo run --release --example quickstart
//!
//! 1. pretrain a tiny dense MiniLlama on the synthetic corpus
//! 2. prune it to 50 % with Wanda
//! 3. fine-tune block-by-block with EBFT (Alg. 1)
//! 4. compare perplexity: dense vs pruned vs fine-tuned
//!
//! This is also the pipeline-API quickstart: build once with
//! `PipelineBuilder`, prune once, recover twice from the shared checkpoint.

use ebft::config::FtConfig;
use ebft::coordinator::{pruner, recovery, PipelineBuilder};
use ebft::data::MarkovCorpus;
use ebft::pretrain;
use ebft::pruning::Pattern;
use ebft::runtime::Session;
use ebft::util::metrics::fmt_ppl;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let session = Session::open_dir(&root.join("artifacts/tiny"))?;
    let corpus = MarkovCorpus::new(session.manifest.dims.vocab, 7);

    println!("[1/4] pretraining tiny MiniLlama (200 steps)...");
    let (dense, report) = pretrain::pretrain(&session, &corpus, 200, 3e-3,
                                             0, 50)?;
    println!("      final train loss {:.3} in {:.1}s", report.final_loss,
             report.secs);
    let dense = ebft::model::DenseModel::resident(dense);

    let pipe = PipelineBuilder::new()
        .session(&session)
        .corpus(&corpus)
        .dense(&dense)
        .ft(FtConfig { calib_seqs: 32, ..FtConfig::default() })
        .eval_seqs(32)
        .build()?;

    println!("[2/4] dense perplexity...");
    let dense_ppl = pipe.dense_ppl()?;

    println!("[3/4] pruning 50% with Wanda...");
    let pruned_ckpt = pipe.prune(pruner("wanda")?,
                                 Pattern::Unstructured(0.5))?;
    let (_, _, pruned) = pipe.recover(&pruned_ckpt, recovery("none")?)?;

    println!("[4/4] EBFT block-wise fine-tuning...");
    let (_, _, tuned) = pipe.recover(&pruned_ckpt, recovery("ebft")?)?;

    println!();
    println!("  dense       ppl {}", fmt_ppl(dense_ppl));
    println!("  wanda@50%   ppl {}", fmt_ppl(pruned.ppl));
    println!("  + EBFT      ppl {}  ({:.1}s fine-tuning)",
             fmt_ppl(tuned.ppl), tuned.ft_secs);
    if let Some(r) = &tuned.ebft_report {
        for b in &r.per_block {
            println!("      block {}: recon loss {:.4} → {:.4}", b.block,
                     b.first_loss, b.last_loss);
        }
    }
    assert!(tuned.ppl <= pruned.ppl,
            "EBFT should not make the pruned model worse");
    println!("\nquickstart OK");
    Ok(())
}
