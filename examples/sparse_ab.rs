//! Sparse-vs-dense A/B gate — the bench-regression job's hard check on
//! the sparse execution subsystem's acceptance criteria.
//!
//!   cargo run --release --example sparse_ab
//!
//! Three gates, each a plain assert so the process exits nonzero (and
//! the CI step fails) on any violation:
//!
//! 1. **End-to-end bit-equality.** One wanda@70% + EBFT smoke cell on
//!    the synthetic tiny manifest (reference backend), run twice — once
//!    with sparse dispatch off, once forced — must produce bit-identical
//!    perplexities. This drives the compressed formats through pruning
//!    stats, the EBFT recovery loop and eval, not just one matmul.
//! 2. **Kernel speedup.** A 70%-sparse masked linear must run faster
//!    through the compressed formats than through the dense masked path
//!    (mask_mul + dense matmul), median wall-clock over several reps,
//!    with bit-equal output. The cell above is too small for its wall
//!    clock to gate reliably, so the measurable-speedup criterion is
//!    pinned here, at the layer shape where the work actually happens.
//! 3. **Checkpoint compression.** The masked pruned params saved in the
//!    compact v2 `.ebft` encoding must be ≤ 50% of the dense v1 size
//!    and reload bit-exactly.
//!
//! Every summary line is prefixed `sparse-ab:` so the CI job summary
//! can grep them out of the log.

use std::time::Instant;

use ebft::bench_support::BenchEnv;
use ebft::config::FtConfig;
use ebft::coordinator::pruner;
use ebft::model::ParamStore;
use ebft::pruning::Pattern;
use ebft::tensor::sparse::{set_sparse_mode, EffWeight, SparseMode};
use ebft::tensor::Tensor;
use ebft::util::Pcg64;

/// Microbench layer shape: one mid-size linear (batch × in → out).
const BATCH: usize = 256;
const K_IN: usize = 512;
const N_OUT: usize = 1024;
/// Timing repetitions per path (median taken).
const REPS: usize = 5;

fn main() -> anyhow::Result<()> {
    let pattern = Pattern::Unstructured(0.7);

    // ---- gate 1: full cell, dense dispatch vs forced sparse ----------
    let env = BenchEnv::open_synthetic()?;
    let ft = FtConfig { calib_seqs: 8, ..FtConfig::default() };
    let pipe = env.pipeline_with(ft)?;

    let prev = set_sparse_mode(SparseMode::Off);
    let t0 = Instant::now();
    let dense_cell = pipe.run_named("wanda", pattern, "ebft")?;
    let dense_secs = t0.elapsed().as_secs_f64();

    set_sparse_mode(SparseMode::Force);
    let t1 = Instant::now();
    let sparse_cell = pipe.run_named("wanda", pattern, "ebft")?;
    let sparse_secs = t1.elapsed().as_secs_f64();
    set_sparse_mode(prev);

    assert_eq!(dense_cell.ppl.to_bits(), sparse_cell.ppl.to_bits(),
               "sparse dispatch changed the cell's perplexity: \
                dense {} vs sparse {}", dense_cell.ppl, sparse_cell.ppl);
    println!("sparse-ab: cell wanda@70%+ebft ppl {:.6} bit-identical \
              across dispatch modes", dense_cell.ppl);
    println!("sparse-ab: cell wall dense {dense_secs:.2}s sparse \
              {sparse_secs:.2}s (x{:.2}, informational)",
             dense_secs / sparse_secs);

    // ---- gate 2: kernel-level speedup at 70% sparsity ----------------
    let mut rng = Pcg64::seeded(7);
    let w = Tensor::randn(&[K_IN, N_OUT], 0.02, &mut rng);
    let mask = Tensor::from_vec(
        &[K_IN, N_OUT],
        (0..K_IN * N_OUT)
            .map(|_| if rng.below(10) < 7 { 0.0 } else { 1.0 })
            .collect());
    let a = Tensor::randn(&[BATCH, K_IN], 1.0, &mut rng);

    // both paths rebuild their effective weight per call, exactly like
    // the reference backend's per-forward masked_eff
    let (y_dense, t_dense) = timed(|| {
        let eff = EffWeight::from_masked_mode(&w, &mask, SparseMode::Off);
        eff.matmul(&a)
    })?;
    let (y_sparse, t_sparse) = timed(|| {
        let eff = EffWeight::from_masked_mode(&w, &mask,
                                              SparseMode::Force);
        eff.matmul(&a)
    })?;
    assert_bits_eq(&y_dense, &y_sparse, "kernel A/B output");

    let nnz = mask.count_nonzero();
    let density = nnz as f64 / mask.numel() as f64;
    let speedup = t_dense / t_sparse;
    println!("sparse-ab: kernel {BATCH}x{K_IN}x{N_OUT} density {:.3} \
              median dense {:.1}ms sparse {:.1}ms speedup x{:.2}",
             density, t_dense * 1e3, t_sparse * 1e3, speedup);
    assert!(speedup > 1.0,
            "sparse path not faster than dense masked path at \
             {:.0}% sparsity (x{speedup:.2})", (1.0 - density) * 100.0);

    // ---- gate 3: compact checkpoint size + exact round-trip ----------
    // wanda leaves pruned weights in place (masks carry the sparsity),
    // so realize the zeros before measuring what compaction buys
    let pruned = pipe.prune(pruner("wanda")?, pattern)?;
    let mut params = pruned.params.clone();
    pruned.masks.apply(&env.session.manifest, &mut params)?;

    let dir = env.runs.join("sparse-ab");
    std::fs::create_dir_all(&dir)?;
    let dense_path = dir.join("params_dense.ebft");
    let sparse_path = dir.join("params_sparse.ebft");
    params.save(&dense_path)?;
    params.save_compact(&sparse_path)?;
    let dense_len = std::fs::metadata(&dense_path)?.len();
    let sparse_len = std::fs::metadata(&sparse_path)?.len();

    let reloaded = ParamStore::load(&sparse_path, &env.session.manifest)?;
    for (t, r) in params.tensors.iter().zip(&reloaded.tensors) {
        assert_bits_eq(t, r, "compact checkpoint round-trip");
    }
    println!("sparse-ab: checkpoint dense {dense_len} B sparse \
              {sparse_len} B ratio {:.1}% round-trip bit-exact",
             sparse_len as f64 / dense_len as f64 * 100.0);
    assert!(sparse_len * 2 <= dense_len,
            "70%-sparse compact checkpoint is {sparse_len} B, more than \
             half the dense {dense_len} B");

    println!("sparse-ab: all gates passed");
    Ok(())
}

/// Median wall-clock over [`REPS`] runs of `f`, plus its (last) output.
fn timed(f: impl Fn() -> anyhow::Result<Tensor>)
         -> anyhow::Result<(Tensor, f64)> {
    let mut times = Vec::with_capacity(REPS);
    let mut out = None;
    for _ in 0..REPS {
        let t0 = Instant::now();
        out = Some(f()?);
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    Ok((out.expect("REPS >= 1"), times[times.len() / 2]))
}

fn assert_bits_eq(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shape mismatch");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert!(x.to_bits() == y.to_bits(),
                "{what}: bit mismatch at flat index {i}: {x} vs {y}");
    }
}
